"""Streaming vs batch search: incremental cost per chunk at equal N.

Measures (a) steady-state ``StreamingIndex`` insert+query latency per
block, (b) end-to-end detector chunk throughput, and (c) offline
``lsh.search`` wall time over the same N fingerprints — the quantity the
streaming path amortizes: arrival of one new chunk costs O(chunk) against
the index instead of an O(N) re-sort of history.

``--memory`` additionally measures the bounded-mode claim: peak host
memory (tracemalloc) and peak buffered candidate-triplet rows of the
sliding-window + rolling-occurrence-filter path over a 1× and a 3× longer
synthetic stream. Flat peaks across the 3× run are the measured evidence
that host pair state is bounded by the window, not the stream length.

``--scenario`` measures the dirty-data claim (ISSUE 4): a gap + duplicated-
block + repeating-glitch-train stream runs through the unguarded and the
quality-guarded paths; the point records guarded chunks/sec, raw spurious-
pair counts for both, the reduction factor (acceptance: ≥ 10×), and the
clean-portion recall (acceptance: unchanged, = 1.0). The point's
``additive`` sub-section (ISSUE 5) repeats the measurement for *additive*
glitch trains — pulses riding the live noise floor, invisible to the
sample-exact duplicate guard, previously only ~2× suppressed — where the
in-dispatch §6.5 occurrence limiter carries the same ≥ 10× acceptance.
``--scenario-only`` updates just the ``scenario`` key of an existing
``BENCH_stream.json`` (the ``make bench-smoke`` hook).

``--assoc`` measures the located-association claim (ISSUE 9): on a
physical-geometry network under cross-station coincidence pressure
(independent repeating-noise bursts at every station), the
moveout-consistency gate cuts ≥3-station false associations relative to
the pairwise §7 baseline while keeping true groups, and the kept groups
locate within the acceptance bound (median origin error ≤ 2 coarse grid
cells). ``--assoc-only`` updates just the ``located_scenario`` key
(the ``make bench-assoc`` hook).

Emits csv lines plus a ``BENCH_stream.json`` trajectory point.
"""
from __future__ import annotations

import argparse
import json
import os
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_lsh_config, csv_line,
                               station_fingerprints, stream_smoke_configs,
                               stream_smoke_dataset, timed)
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.detect import DetectConfig
from repro.core.synth import (ScenarioConfig, SynthConfig, make_dataset,
                              make_scenario_dataset)
from repro.stream import StreamingDetector, StreamConfig
from repro.stream import index as SI
from repro.stream.engine import ingest_chunks


def memory_point(base_duration_s: float = 600.0) -> dict:
    """Peak host memory of the rolling-filter path at 1× vs 3× stream.

    The detect/stream configs are built once (``stream_smoke_configs``);
    only the synthetic trace differs between the 1× and 3× runs.
    """
    cfg, scfg = stream_smoke_configs(bounded=True)
    out = {}
    for mult in (1, 3):
        ds = stream_smoke_dataset(duration_s=base_duration_s * mult,
                                  events_per_source=4 * mult)
        wf = ds.waveforms[0]
        det = StreamingDetector(cfg, scfg, n_stations=1)
        chunks = [wf[s: s + 6000] for s in range(0, wf.size, 6000)]
        for c in chunks[:4]:          # compile + freeze stats untraced
            det.push(c)
        det.stations[0].flush()       # pre-compile the masked-tail step too
        tracemalloc.start()
        for c in chunks[4:]:
            det.push(c)
        det.stations[0].flush()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        st = det.stations[0]
        out[f"x{mult}"] = {
            "samples": int(wf.size),
            "fingerprints": int(st.ring.next_fp),
            "pairs_seen": int(st.filter.pairs_seen),
            "windows_closed": int(st.filter.windows_closed),
            "peak_traced_mb": round(peak / 2**20, 3),
            "peak_buffered_triplets": int(st.peak_tri_rows),
            "final_buffered_triplets": int(st.host_state_rows()),
        }
        csv_line(f"stream.memory_x{mult}", peak / 2**20,
                 f"unit=MB triplets={st.peak_tri_rows} "
                 f"windows={st.filter.windows_closed}")
    out["peak_mb_ratio_x3_over_x1"] = round(
        out["x3"]["peak_traced_mb"] / max(out["x1"]["peak_traced_mb"],
                                          1e-9), 3)
    out["peak_triplets_ratio_x3_over_x1"] = round(
        out["x3"]["peak_buffered_triplets"]
        / max(out["x1"]["peak_buffered_triplets"], 1), 3)
    return out


def bench_scenario(duration_s: float = 600.0) -> ScenarioConfig:
    """The pinned gap + duplicate + glitch-train stream the scenario
    benchmark and the fault-injection tests share. The glitch is one long
    replace-mode train — a channel glitching continuously for 150 s —
    which is both the realistic shape of the pathology (paper §6.5:
    glitches repeating every few seconds for extended spans) and the
    volume regime the guards target."""
    return ScenarioConfig(
        base=SynthConfig(duration_s=duration_s, n_stations=1, n_sources=2,
                         events_per_source=5, event_snr=3.0, seed=3),
        n_gaps=2, gap_dur_s=(2.0, 5.0),
        n_dup_blocks=1, dup_block_dur_s=20.0, dup_spacing_s=60.0,
        glitch_stations=(0,), glitch_trains=1,
        glitch_train_dur_s=duration_s / 4.0, seed=1)


def additive_bench_scenario(duration_s: float = 600.0) -> ScenarioConfig:
    """The pinned *additive* glitch-train stream (ISSUE 5): the pulses
    ride on the live noise floor (``glitch_replace=False``), so train
    fingerprints are never sample-exact — the duplicate guard cannot see
    them and the saturation quarantine alone only managed ~2×. The
    in-dispatch occurrence limiter is what carries the ≥10× acceptance
    here. Shared with ``tests/test_scenarios.py``."""
    return ScenarioConfig(
        base=SynthConfig(duration_s=duration_s, n_stations=1, n_sources=2,
                         events_per_source=5, event_snr=3.0, seed=3),
        glitch_stations=(0,), glitch_trains=4,
        glitch_train_dur_s=duration_s / 15.0, glitch_replace=False, seed=1)


def _scenario_run(cfg, scfg, wf, med_mad, n_chunks=16, timing=False):
    """One detector pass → (raw emitted pair set, detector, chunks/sec)."""
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    res = ingest_chunks(det, wf, n_chunks=n_chunks,
                        warmup_chunks=4 if timing else 0)
    st = det.stations[0]
    st.flush()
    tri = (np.concatenate(st.triplets, axis=0) if st.triplets
           else np.zeros((0, 3), np.int64))
    raw = set(zip(tri[:, 0].tolist(), tri[:, 1].tolist()))
    cps = res["timed_chunks"] / max(res["wall_s"], 1e-9) if timing else None
    return raw, det, cps


def scenario_point(duration_s: float = 600.0) -> dict:
    """Dirty-stream robustness point: spurious suppression + throughput.

    Three runs over the same scenario: clean trace through the guarded
    path (the golden pair set), dirty trace unguarded, dirty trace
    guarded (timed). Spurious = emitted pairs not in the golden set —
    the raw candidate stream is the quantity that swamped the paper's
    post-processing until quality controls were added, so it is measured
    *before* the occurrence filter.
    """
    from repro.configs.fast_seismic import (smoke_config,
                                            stream_dirty_smoke_config,
                                            stream_smoke_config)
    from benchmarks.common import frozen_smoke_stats
    cfg = smoke_config()
    scen = make_scenario_dataset(bench_scenario(duration_s))
    wf_clean = scen.clean.waveforms[0]
    wf_dirty = scen.waveforms[0]
    med_mad = frozen_smoke_stats(cfg, wf_clean)
    guarded_cfg = stream_dirty_smoke_config()

    golden, _, _ = _scenario_run(cfg, guarded_cfg, wf_clean, med_mad)
    unguarded, _, _ = _scenario_run(cfg, stream_smoke_config(), wf_dirty,
                                    med_mad)
    guarded, det, cps = _scenario_run(cfg, guarded_cfg, wf_dirty, med_mad,
                                      timing=True)
    st = det.stations[0]

    fcfg = cfg.fingerprint
    ok = set(scen.clean_fp_ids(0, fcfg.window_samples,
                               fcfg.lag_samples).tolist())
    ref = {p for p in golden if p[0] in ok and p[1] in ok}
    got = {p for p in guarded if p[0] in ok and p[1] in ok}
    spurious_unguarded = len(unguarded - golden)
    spurious_guarded = len(guarded - golden)
    point = {
        "schema": "bench-stream-scenario/v2",
        "duration_s": duration_s,
        "pathologies": {k: len(v) for k, v in scen.injections.items()},
        "golden_pairs": len(golden),
        "spurious_unguarded": spurious_unguarded,
        "spurious_guarded": spurious_guarded,
        "spurious_reduction": round(
            spurious_unguarded / max(spurious_guarded, 1), 2),
        "clean_portion_pairs": len(ref),
        "clean_portion_recall": round(
            len(ref & got) / max(len(ref), 1), 4),
        "guarded_chunks_per_s": round(cps, 2),
        "quality": st.quality_summary(),
        # the ISSUE-6 structured view of the guarded dirty run: drop
        # breakdown, wall histograms, spans, watchdog — one schema shared
        # with serve_detect / bench_e2e / the tier-1 schema test
        "metrics": det.metrics_snapshot(),
        "additive": additive_scenario_point(duration_s),
    }
    csv_line("stream.scenario_spurious_reduction",
             point["spurious_reduction"],
             f"unguarded={spurious_unguarded} guarded={spurious_guarded} "
             f"recall={point['clean_portion_recall']}")
    return point


def additive_scenario_point(duration_s: float = 600.0) -> dict:
    """The in-dispatch occurrence limiter's acceptance point: additive
    glitch trains, ≥10× raw spurious-pair suppression with clean-portion
    recall unchanged."""
    from repro.configs.fast_seismic import (smoke_config,
                                            stream_dirty_smoke_config,
                                            stream_smoke_config)
    from benchmarks.common import frozen_smoke_stats
    cfg = smoke_config()
    scen = make_scenario_dataset(additive_bench_scenario(duration_s))
    med_mad = frozen_smoke_stats(cfg, scen.clean.waveforms[0])
    guarded_cfg = stream_dirty_smoke_config()

    golden, _, _ = _scenario_run(cfg, guarded_cfg, scen.clean.waveforms[0],
                                 med_mad)
    unguarded, _, _ = _scenario_run(cfg, stream_smoke_config(),
                                    scen.waveforms[0], med_mad)
    guarded, det, cps = _scenario_run(cfg, guarded_cfg, scen.waveforms[0],
                                      med_mad, timing=True)
    st = det.stations[0]
    fcfg = cfg.fingerprint
    ok = set(scen.clean_fp_ids(0, fcfg.window_samples,
                               fcfg.lag_samples).tolist())
    ref = {p for p in golden if p[0] in ok and p[1] in ok}
    got = {p for p in guarded if p[0] in ok and p[1] in ok}
    su, sg = len(unguarded - golden), len(guarded - golden)
    point = {
        "glitch_trains": len(scen.injections["glitch_trains"]),
        "golden_pairs": len(golden),
        "spurious_unguarded": su,
        "spurious_guarded": sg,
        "spurious_reduction": round(su / max(sg, 1), 2),
        "clean_portion_recall": round(len(ref & got) / max(len(ref), 1), 4),
        "limited_pairs": st.quality_summary()["limited_pairs"],
        "guarded_chunks_per_s": round(cps, 2),
    }
    csv_line("stream.additive_glitch_reduction",
             point["spurious_reduction"],
             f"unguarded={su} guarded={sg} "
             f"limited_pairs={point['limited_pairs']} "
             f"recall={point['clean_portion_recall']}")
    return point


def located_scenario_point(duration_s: float = 600.0) -> dict:
    """Moveout-consistency A/B (ISSUE 9): cross-station false
    associations under coincidence pressure, pairwise §7 baseline vs the
    migration-stack gate.

    Independent repeating-noise bursts at every station create per-
    station repeats whose (dt, onset) coincide across stations by chance
    — exactly the pairwise association's blind spot, since it never
    checks that the group's onsets fit *any* physical moveout. Three
    runs over the same physical-geometry network: the clean trace (no
    bursts, locate off) gives the golden association set; the noisy
    trace runs once with ``reject_inconsistent=False`` (the pairwise
    baseline) and once gated. A detection matching no golden
    (dt, onset) within the association tolerances is a false
    association. Two stations always admit a perfect-residual origin, so
    the gate is discriminative for ≥3-station groups — the A/B is
    recorded on those.
    """
    import dataclasses
    from repro.configs.fast_seismic import locate_config
    from repro.core import (AlignConfig, FingerprintConfig, LSHConfig)
    from repro.core.detect import detect_events

    # the Fig-7 sensitivity tier (tests/test_detect_e2e.py shape): 1 s
    # lags, short windows, 100 tables, permissive clustering — the
    # regime where repeating noise actually reaches the association
    # layer instead of being diluted inside a long analysis window
    fcfg = FingerprintConfig(img_time=16, img_hop=4, top_k=200,
                             mad_sample_rate=1.0)
    lcfg = LSHConfig(n_tables=100, n_funcs=4, n_matches=2, bucket_cap=8,
                     min_dt=fcfg.overlap_fingerprints, occurrence_frac=0.05)
    acfg = AlignConfig(channel_threshold=3, min_cluster_sim=4,
                       min_cluster_size=1, min_stations=2,
                       onset_tol=int(10 * fcfg.fs / fcfg.lag_samples))
    cfg = DetectConfig(fingerprint=fcfg, lsh=lcfg, align=acfg,
                       locate=locate_config())
    n_st = 6

    def mk(noisy):
        # period shared network-wide, phase per-station: inter-burst
        # times agree across stations, onsets fit no moveout
        return make_dataset(SynthConfig(
            duration_s=duration_s, n_stations=n_st, n_sources=3,
            events_per_source=4, event_snr=3.0, seed=3,
            physical_geometry=True,
            repeating_noise_stations=tuple(range(n_st)) if noisy else (),
            repeating_noise_period_s=45.0, repeating_noise_amp=4.0))

    ds_clean, ds = mk(False), mk(True)   # same events/geometry, ± bursts

    def run(wf, locate):
        c = dataclasses.replace(cfg, locate=locate)
        det, _, _, stats = detect_events(
            wf, c, station_xy=ds.station_xy if locate else None)
        return {k: np.asarray(v) for k, v in det.items()}, stats

    golden, _ = run(ds_clean.waveforms, None)
    base, _ = run(ds.waveforms, dataclasses.replace(
        cfg.locate, reject_inconsistent=False))
    gated, gstats = run(ds.waveforms, cfg.locate)

    acfg = cfg.align
    gv = golden["valid"]
    gold = np.stack([golden["dt"][gv], golden["onset"][gv]], axis=1)

    def classify(det, min_st):
        idx = np.nonzero(det["valid"] & (det["n_stations"] >= min_st))[0]
        is_true = np.array([bool(np.any(
            (np.abs(gold[:, 0] - det["dt"][g]) <= acfg.dt_tol)
            & (np.abs(gold[:, 1] - det["onset"][g]) <= acfg.onset_tol)))
            for g in idx], bool)
        return idx, is_true

    bi, bt = classify(base, 3)
    gi, gt = classify(gated, 3)
    false_base, false_gated = int((~bt).sum()), int((~gt).sum())

    # origin accuracy over the well-constrained (≥4-station) true groups
    errs = []
    for g, t in zip(gi, gt):
        if (t and gated["n_stations"][g] >= 4
                and np.isfinite(gated["x_km"][g])):
            p = np.array([gated["x_km"][g], gated["y_km"][g]])
            errs.append(float(np.min(np.linalg.norm(
                ds.source_xy - p, axis=1))))
    cell = cfg.locate.coarse_cell_km
    med = float(np.median(errs)) if errs else None
    point = {
        "schema": "bench-stream-located/v1",
        "duration_s": duration_s,
        "stations": n_st,
        "golden_groups": int(gv.sum()),
        "multi3_groups_pairwise": int(bi.size),
        "multi3_groups_gated": int(gi.size),
        "false_assoc_pairwise": false_base,
        "false_assoc_gated": false_gated,
        "false_assoc_reduction": round(false_base / max(false_gated, 1), 2),
        "true_kept_pairwise": int(bt.sum()),
        "true_kept_gated": int(gt.sum()),
        "moveout_rejected": int(gstats.get("moveout_rejected", 0)),
        "located_groups": int(np.isfinite(
            gated["x_km"][gated["valid"]]).sum()),
        "median_origin_err_km": round(med, 2) if errs else None,
        "median_origin_err_cells": (round(med / cell, 2)
                                    if errs else None),
        "coarse_cell_km": round(cell, 3),
    }
    csv_line("stream.located_false_assoc_reduction",
             point["false_assoc_reduction"],
             f"pairwise={false_base} gated={false_gated} "
             f"true_kept={int(gt.sum())}/{int(bt.sum())} "
             f"origin_err_cells={point['median_origin_err_cells']}")
    return point


def _write_point(point: dict) -> str:
    out = os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                       "BENCH_stream.json")
    with open(out, "w") as f:
        json.dump(point, f, indent=2)
    print(f"# wrote {out}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--memory", action="store_true",
                    help="also record rolling-filter peak host memory "
                         "(1x vs 3x stream) into BENCH_stream.json")
    ap.add_argument("--memory-duration-s", type=float, default=600.0)
    ap.add_argument("--scenario", action="store_true",
                    help="also record the dirty-stream (gap + glitch) "
                         "robustness point into BENCH_stream.json")
    ap.add_argument("--scenario-only", action="store_true",
                    help="update only the scenario key of an existing "
                         "BENCH_stream.json (tier-1-safe smoke)")
    ap.add_argument("--scenario-duration-s", type=float, default=600.0)
    ap.add_argument("--assoc", action="store_true",
                    help="also record the located-association moveout "
                         "A/B point into BENCH_stream.json")
    ap.add_argument("--assoc-only", action="store_true",
                    help="update only the located_scenario key of an "
                         "existing BENCH_stream.json (make bench-assoc)")
    ap.add_argument("--assoc-duration-s", type=float, default=600.0)
    args = ap.parse_args(argv)
    if args.scenario_only or args.assoc_only:
        path = os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                            "BENCH_stream.json")
        point = {}
        if os.path.exists(path):
            with open(path) as f:
                point = json.load(f)
        if args.scenario_only:
            point["scenario"] = scenario_point(args.scenario_duration_s)
        if args.assoc_only:
            point["located_scenario"] = located_scenario_point(
                args.assoc_duration_s)
        _write_point(point)
        return point
    ds, fcfg, bits, packed = station_fingerprints(station=1)
    n = bits.shape[0]
    lcfg = bench_lsh_config(fcfg)
    mp = L.hash_mappings(fcfg.fp_dim, lcfg)
    sigs = L.signatures(bits, mp, lcfg)

    # --- offline: full sort-based search at N (what a re-run would pay)
    t_search, _ = timed(lambda: L.candidate_pairs(sigs, lcfg).valid.sum())
    csv_line("stream.batch_search_at_N", t_search * 1e6, f"N={n}")

    # --- streaming index: steady-state insert+query per block
    block = 64
    state = SI.init_index(lcfg, SI.StreamIndexConfig(n_buckets=2048,
                                                     bucket_cap=8))
    ids0 = jnp.arange(block, dtype=jnp.int32)
    # preload the index to ~N resident entries, then time one more block
    for i in range(0, (n // block) * block, block):
        state = SI.insert(state, sigs[i:i + block], ids0 + i, lcfg)
    sb = sigs[:block]
    holder = {"state": state, "next": n}

    def insert_query():
        # rolling steady state (insert donates its input buffers)
        ids = ids0 + holder["next"]
        holder["next"] += block
        holder["state"] = SI.insert(holder["state"], sb, ids, lcfg)
        return SI.query(holder["state"], sb, ids, lcfg).valid.sum()

    t_iq, _ = timed(insert_query)
    csv_line("stream.insert_query_block", t_iq * 1e6,
             f"block={block} resident≈{n} "
             f"speedup_vs_resort={t_search / max(t_iq, 1e-12):.1f}x")

    # --- end-to-end detector chunk throughput (incl. fingerprinting)
    cfg = DetectConfig(fingerprint=fcfg, lsh=lcfg)
    det = StreamingDetector(
        cfg, StreamConfig(block_fingerprints=block,
                          index=SI.StreamIndexConfig(n_buckets=2048,
                                                     bucket_cap=8),
                          stats_warmup_blocks=2),
        n_stations=1)
    # shared ingest loop (same code path as serve_detect / bench_e2e)
    res = ingest_chunks(det, ds.waveforms[1], n_chunks=16, warmup_chunks=4)
    wall, n_done = res["wall_s"], res["timed_chunks"]
    ing = det.stations[0].stats.summary()
    csv_line("stream.detector_chunk", wall / n_done * 1e6,
             f"chunks_per_s={n_done / max(wall, 1e-9):.1f} "
             f"samples_per_s={res['samples'] / max(wall, 1e-9):.0f}")

    point = {
        "n_fingerprints": int(n),
        "batch_search_us": round(t_search * 1e6, 1),
        "insert_query_block_us": round(t_iq * 1e6, 1),
        "block": block,
        "amortized_speedup": round(t_search / max(t_iq, 1e-12), 2),
        "detector_chunks_per_s": round(n_done / max(wall, 1e-9), 2),
        "detector_samples_per_s": round(
            res["samples"] / max(wall, 1e-9), 1),
        "ingest": ing,
    }
    if args.memory:
        point["rolling_memory"] = memory_point(args.memory_duration_s)
    if args.scenario:
        point["scenario"] = scenario_point(args.scenario_duration_s)
    if args.assoc:
        point["located_scenario"] = located_scenario_point(
            args.assoc_duration_s)
    _write_point(point)
    return point


if __name__ == "__main__":
    main()
