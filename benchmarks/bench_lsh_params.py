"""Paper Figure 12 (+Figure 6): LSH parameter effect on lookups/runtime.

Parameter sets with near-identical theoretical S-curves but increasing
hash-function counts; reports selectivity (avg lookups per query — the
paper's machine-independent proxy), runtime, and the theoretical s50.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_lsh_config, csv_line,
                               station_fingerprints, timed)
from repro.core import lsh as L
from repro.core import theory


def main():
    ds, fcfg, bits, packed = station_fingerprints(station=0)
    rows = []
    for k, m in ((2, 9), (4, 2), (6, 1)):
        lcfg = bench_lsh_config(fcfg, n_funcs=k, n_matches=m)
        mp = L.hash_mappings(fcfg.fp_dim, lcfg)
        sigs = L.signatures(bits, mp, lcfg)
        stats = {kk: float(v) for kk, v in L.bucket_stats(sigs).items()}
        t, pairs = timed(lambda: L.candidate_pairs(sigs, lcfg))
        s50 = theory.s_curve_threshold(k, m, lcfg.n_tables)
        rows.append((k, m, stats, t))
        csv_line(f"lsh_params.k{k}m{m}", t * 1e6,
                 f"s50={s50:.3f} lookups/query="
                 f"{stats['avg_lookups_per_query']:.1f} "
                 f"selectivity={stats['selectivity']:.5f} "
                 f"max_bucket={stats['max_bucket']:.0f} "
                 f"pairs={int(np.asarray(pairs.count()))}")
    # Figure 6: report the matched S-curves
    for s in (0.2, 0.35, 0.5):
        probs = ",".join(
            f"k{k}m{m}:{theory.detection_probability(s, k, m, 100):.3f}"
            for k, m in ((2, 9), (4, 2), (6, 1)))
        csv_line(f"lsh_params.theory_s{s}", 0.0, probs)
    return rows


if __name__ == "__main__":
    main()
