"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json and prints, per (arch × shape × mesh): the
three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO ratio, and
bytes/device — the §Roofline contract.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load_cells(out_dir: str = "artifacts/dryrun", mesh: str | None = None,
               tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        try:
            r = json.load(open(p))
        except Exception:
            continue
        if r.get("tag", "") != tag:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        cells.append(r)
    return cells


def fmt_row(r: dict) -> str:
    if r.get("status") != "ok":
        return (f"{r['arch']:22s} {r['shape']:13s} {r['mesh']:6s} "
                f"FAILED: {r.get('error', '')[:60]}")
    rf = r["roofline"]
    mem = r.get("memory", {})
    hbm = (mem.get("argument_size_in_bytes", 0)
           + mem.get("output_size_in_bytes", 0)
           - mem.get("alias_size_in_bytes", 0)
           + mem.get("temp_size_in_bytes", 0))
    return (f"{r['arch']:22s} {r['shape']:13s} {r['mesh']:6s} "
            f"{rf['compute_s']:9.4f} {rf['memory_s']:9.4f} "
            f"{rf['collective_s']:9.4f} {rf['dominant'][:-2]:>10s} "
            f"{rf['useful_flops_ratio']:7.3f} "
            f"{rf['roofline_fraction']:7.3f} {hbm/2**30:8.2f}")


def main(out_dir: str = "artifacts/dryrun"):
    cells = load_cells(out_dir)
    hdr = (f"{'arch':22s} {'shape':13s} {'mesh':6s} "
           f"{'compute_s':>9s} {'memory_s':>9s} {'collect_s':>9s} "
           f"{'dominant':>10s} {'useful':>7s} {'roof_fr':>7s} "
           f"{'HBM_GiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in cells:
        print(fmt_row(r))
    ok = sum(r.get("status") == "ok" for r in cells)
    print(f"\n{ok}/{len(cells)} cells ok")
    return cells


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
