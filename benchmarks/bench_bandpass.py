"""Paper Figure 11: bandpass filter effect on search runtime/output size.

Station 1 carries a 30 Hz modulated hum; without the band cut the hum
creates repeating out-of-band matches (runtime + output blow-up).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_dataset, bench_fp_config,
                               bench_lsh_config, csv_line, timed)
from repro.core import fingerprint as F
from repro.core import lsh as L


def main():
    ds = bench_dataset(duration_s=600.0, with_noise=False, with_hum=True)
    x = jnp.asarray(ds.waveforms[1])
    rows = []
    for name, lo, hi in (("bp0-50", 0.01, 50.0), ("bp1-20", 1.0, 20.0),
                         ("bp3-20", 3.0, 20.0)):
        fcfg = bench_fp_config(band_lo_hz=lo, band_hi_hz=hi)
        bits, _ = F.fingerprints_from_waveform(x, fcfg)
        lcfg = bench_lsh_config(fcfg)
        mp = L.hash_mappings(fcfg.fp_dim, lcfg)
        sigs = L.signatures(bits, mp, lcfg)
        t, pairs = timed(lambda: L.candidate_pairs(sigs, lcfg))
        n_pairs = int(np.asarray(pairs.count()))
        rows.append((name, t, n_pairs))
        csv_line(f"bandpass.{name}", t * 1e6, f"pairs={n_pairs}")
    return rows


if __name__ == "__main__":
    main()
