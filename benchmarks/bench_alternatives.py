"""Paper Table 2 / Appendix A: MinHash LSH vs exact join vs SimHash.

Per-fingerprint query cost of: our Min-Max LSH; an exact all-pairs Jaccard
join (vectorized O(N²) — the set-similarity-join stand-in); and a SimHash
(random-hyperplane) LSH at matched table/бит budget. Also reports the
false-negative rate of each approximate method vs the exact join at
J ≥ 0.5 (the paper's threshold; FAST measured ~6.6% FN).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_lsh_config, csv_line,
                               station_fingerprints, timed)
from repro.core import lsh as L
from repro.utils import hash_u32, mix32, segment_ids_from_starts, \
    segment_starts


def simhash_signatures(bits: jax.Array, n_tables: int, bits_per_table: int,
                       seed: int = 7) -> jax.Array:
    """Random-hyperplane LSH over ±1-encoded binary vectors."""
    n, d = bits.shape
    h = n_tables * bits_per_table
    key = jax.random.PRNGKey(seed)
    planes = jax.random.normal(key, (d, h), jnp.float32)
    x = bits.astype(jnp.float32) * 2 - 1
    proj = x @ planes > 0  # (N, h)
    proj = proj.reshape(n, n_tables, bits_per_table)
    weights = (2 ** jnp.arange(bits_per_table, dtype=jnp.uint32))
    return (proj.astype(jnp.uint32) * weights).sum(-1).astype(jnp.uint32)


def pairs_from_sigs(sigs, cfg):
    return L.candidate_pairs(sigs, cfg)


def main():
    # larger corpus so the O(N²) join's quadratic cost is visible
    ds, fcfg, bits, packed = station_fingerprints(station=1,
                                                  duration_s=2400.0)
    n = bits.shape[0]
    lcfg = bench_lsh_config(fcfg, n_funcs=4, n_matches=2,
                            occurrence_frac=0.0)

    # exact join (vectorized brute force)
    def exact():
        fpb = bits.astype(jnp.float32)
        inter = fpb @ fpb.T
        sizes = fpb.sum(1)
        union = sizes[:, None] + sizes[None, :] - inter
        return inter / jnp.maximum(union, 1.0)

    t_exact, jac = timed(exact, repeats=2)
    jac = np.asarray(jac)
    iu = np.triu_indices(n, k=lcfg.min_dt)
    truth = {(int(a), int(b)) for a, b in zip(*iu)
             if jac[a, b] >= 0.5}

    def fn_rate(pairs):
        found = {(int(a), int(b)) for a, b, v in
                 zip(np.asarray(pairs.idx1), np.asarray(pairs.idx2),
                     np.asarray(pairs.valid)) if v}
        if not truth:
            return 0.0
        return 1.0 - len(truth & found) / len(truth)

    # our Min-Max LSH
    mp = L.hash_mappings(fcfg.fp_dim, lcfg)
    sigs = L.signatures(bits, mp, lcfg)
    t_lsh, pairs = timed(lambda: pairs_from_sigs(sigs, lcfg), repeats=2)
    fn_lsh = fn_rate(pairs)

    # SimHash at matched budget (t tables × 16 bits)
    sim_sigs = simhash_signatures(bits, lcfg.n_tables, 16)
    t_sim, sim_pairs = timed(lambda: pairs_from_sigs(sim_sigs, lcfg),
                             repeats=2)
    fn_sim = fn_rate(sim_pairs)

    per_q = lambda t: t / n * 1e6
    csv_line("alternatives.minmax_lsh", per_q(t_lsh),
             f"fn_rate={fn_lsh:.3f} total_s={t_lsh:.3f}")
    csv_line("alternatives.exact_join", per_q(t_exact),
             f"fn_rate=0.0 total_s={t_exact:.3f} "
             f"speedup_vs_lsh={t_exact/max(t_lsh,1e-9):.1f}x")
    csv_line("alternatives.simhash", per_q(t_sim),
             f"fn_rate={fn_sim:.3f} total_s={t_sim:.3f}")
    return {"lsh": (t_lsh, fn_lsh), "exact": (t_exact, 0.0),
            "simhash": (t_sim, fn_sim)}


if __name__ == "__main__":
    main()
