"""End-to-end streaming hot-path benchmark (ISSUE 3): BENCH_e2e.json.

Measures the fused single-dispatch chunk step against the unfused
pipeline at the real-time **latency configuration**
(``configs.fast_seismic.latency_config``: short blocks for low alert
latency — the regime where per-stage dispatch overhead, not FLOPs, bounds
throughput), at three granularities:

* **step**: steady-state per-block wall of (a) the fused single dispatch,
  (b) the PR-1/2 two-call chain (``block_coeffs`` + ``stream_step``), and
  (c) the fully unfused five-stage chain — fingerprint, binarize,
  signatures, insert, query as separate jitted calls with host
  round-trips between them (the "tuned in isolation" pipeline of the
  paper's motivation, which the fused step replaces).
* **e2e**: ``StreamingDetector.push`` chunks/sec, fused vs unfused at
  1 station and the vmapped station pool at 1 / 4 / 8 stations. All
  points are timed **interleaved** (every detector sees chunk k before
  any sees chunk k+1) and summarized by median per-push wall, so
  shared-machine noise phases hit every point equally instead of
  skewing whichever point they coincide with.
* **memory**: retained device bytes per chunk after warmup
  (``jax.live_arrays`` delta — 0 means every steady-state buffer is a
  donated in-place reuse) and peak host MB (tracemalloc), from a
  separate per-point pass.
* **offline_replay** (ISSUE 5): the unified batch driver —
  ``detect_events`` replaying an archive through the pooled streaming
  core, one fused dispatch per block for all stations — against a
  benchmark-local copy of the legacy host loop (per-station
  fingerprint → signatures → sort-based search → filter chains with
  blocking syncs between stages; the code this PR deleted from
  ``core/detect.py``), at 1/4/8 stations. Records batch blocks/sec and
  the legacy-vs-unified speedup (acceptance: unified ≥ legacy at 4
  stations on the quick run).
* **emission** (ISSUE 8): the device-side pair-compaction A/B at the
  paper-scale table count (t=100), compaction+verify on vs the dense
  t × N × cap emission, at 1 / 4 / 8 stations. Every point records the
  chunk-wall p50 *split* — fused device step vs host tail — plus the
  device→host pair bytes per block, so the O(T·N·C) → O(P) emission-
  pipe shrink is measured, not asserted. The stream is seeded with
  grid-aligned repeating events (``common.seed_repeating_events``) so
  every point emits real pairs; the v2 benchmark's streaming points all
  recorded ``pairs: 0`` and never exercised the path they timed.
  ``--emit`` refreshes only this section (``make bench-emit``).
* **sharded_pool** (ISSUE 10): the mesh-sharded station pool scaling
  grid. Each point forks a child interpreter under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=<d>`` (device count
  is fixed at backend init, so every device count needs its own
  process), streams identical repeat-seeded waveforms through the
  sharded pool and through the single-device ``vmap`` baseline, and
  records aggregate chunks/s, **exact** device-step percentiles, and
  per-station pair counts for the bit-parity check. ``--sharded``
  refreshes only this section (``make bench-sharded``).

Schema-stable output: ``BENCH_e2e.json`` with ``schema: "bench-e2e/v4"``
(v4: the ``sharded_pool`` device grid, and the per-point device-step/
host-tail percentiles are now **exact** wall-clock quantiles from raw
telemetry samples — the v3 values came from the log-bucketed registry
histograms, whose ``percentile()`` returns the bucket upper edge and
quantized every sub-2ms step onto 1.9531 ms; the histogram-derived
values remain under ``*_hist`` keys), a config hash, per-point
chunks/sec, and the headline ratios (fused speedup vs the unfused
chain; 4-/8-station pool wall vs 1-station; unified-batch speedup vs
the legacy loop; emission byte reduction + host-tail speedup; sharded
pool speedup at 8 stations × 8 devices). ``--quick`` shrinks the
stream for the tier-1-safe smoke invocation (``make bench-smoke`` /
the slow-marked pytest guard).
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import pathlib
import subprocess
import sys
import time
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (csv_line, frozen_smoke_stats,
                               seed_repeating_events)
from repro.configs.fast_seismic import (latency_config, smoke_config,
                                        stream_latency_smoke_config,
                                        stream_sharded_smoke_config)
from repro.core import align as A
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.detect import detect_events, replay_config
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import engine as E
from repro.stream import fused as FU
from repro.stream import index as SI
from repro.stream.engine import StreamingDetector

SCHEMA = "bench-e2e/v4"

# (stations, fused) points; (1, False) is the unfused e2e reference
SPECS = [(1, True), (1, False), (4, True), (8, True)]


def pair_bytes_per_block(lcfg, scfg) -> int:
    """Device→host bytes one station's per-block pair emission costs.

    Dense: t × block × cap slots of (idx1, idx2, sim) int32/float32 +
    a valid byte = 13 B/slot. Compacted: ``max_pairs_per_block`` slots,
    +4 B/slot for the exact-Jaccard channel when verify is on."""
    if getattr(scfg, "max_pairs_per_block", 0) > 0:
        per = 13 + (4 if scfg.verify_jaccard else 0)
        return scfg.max_pairs_per_block * per
    return (lcfg.n_tables * scfg.block_fingerprints
            * scfg.index.bucket_cap) * 13


def _wall_split(det) -> dict:
    """p50 of the fused-dispatch and host-tail walls over the run
    (warmup pushes included — medians are robust to the handful of
    compile-adjacent outliers).

    The primary keys are **exact** quantiles over the raw wall samples
    (``telemetry.capture_raw_walls``, enabled by ``_detector``); the
    log-bucketed registry-histogram values — whose ``percentile()``
    returns the bucket upper edge and quantized every sub-2ms step onto
    the same 1.9531 ms — stay available under ``*_hist`` keys."""
    reg = det.telemetry.registry
    out = {
        "device_step_ms_p50_hist": round(
            reg.histogram_merged("fused_step_wall_seconds")
            .percentile(0.5) * 1e3, 4),
        "host_tail_ms_p50_hist": round(
            reg.histogram_merged("host_tail_wall_seconds")
            .percentile(0.5) * 1e3, 4),
    }
    raw = det.telemetry.raw_walls or {}
    for key, name in (("fused_step", "device_step_ms_p50"),
                      ("host_tail", "host_tail_ms_p50")):
        samples = raw.get(key)
        out[name] = (round(float(np.percentile(samples, 50)) * 1e3, 4)
                     if samples else out[f"{name}_hist"])
    return out


def config_hash(cfg, scfg) -> str:
    blob = json.dumps(
        {"cfg": dataclasses.asdict(cfg), "scfg": dataclasses.asdict(scfg)},
        sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _live_bytes() -> int:
    return sum(a.nbytes for a in jax.live_arrays())


def _timeit(fn, repeats: int, batches: int = 5) -> float:
    """Min-of-batches per-call seconds (robust to shared-machine noise:
    the minimum batch is the least-perturbed measurement)."""
    fn()
    fn()
    per = max(1, repeats // batches)
    best = float("inf")
    for _ in range(batches):
        t0 = time.perf_counter()
        for _ in range(per):
            fn()
        best = min(best, (time.perf_counter() - t0) / per)
    return best


def _detector(cfg, scfg, n_stations, fused, med_mad):
    scfg = dataclasses.replace(scfg, fused=fused, pooled=fused)
    det = StreamingDetector(cfg, scfg, n_stations=n_stations,
                            med_mad=med_mad)
    det.telemetry.capture_raw_walls()   # exact percentiles (_wall_split)
    return det


# ---------------------------------------------------------------------------
# step-level: one block through each pipeline shape
# ---------------------------------------------------------------------------


def step_points(cfg, scfg, repeats: int) -> dict:
    fcfg, lcfg = cfg.fingerprint, cfg.lsh
    block = scfg.block_fingerprints
    rng = np.random.default_rng(0)
    med = jnp.zeros(fcfg.n_coeff)
    mad = jnp.ones(fcfg.n_coeff)
    mp = L.hash_mappings(fcfg.fp_dim, lcfg)
    blockw = jnp.asarray(
        rng.standard_normal(fcfg.block_samples(block)).astype(np.float32))
    adv = blockw[-block * fcfg.lag_samples:]
    ids = jnp.arange(block, dtype=jnp.int32)
    vmask = jnp.ones(block, bool)

    # (a) fused single dispatch (donated state, device halo)
    hold = {"s": FU.init_state(SI.init_index(lcfg, scfg.index),
                               fcfg.halo_samples, med, mad)}

    def fused_step():
        hold["s"], p, _ = FU.step_advance(hold["s"], adv, mp, jnp.int32(0),
                                          fcfg, lcfg, 0)
        jax.block_until_ready(p.valid)

    t_fused = _timeit(fused_step, repeats)

    # (b) the PR-1/2 two-call chain
    hold2 = {"s": SI.init_index(lcfg, scfg.index)}

    def two_call():
        coeffs = E.block_coeffs(blockw, fcfg)
        hold2["s"], p, _ = E.stream_step(hold2["s"], coeffs, med, mad, mp,
                                         jnp.int32(0), vmask, fcfg, lcfg, 0)
        jax.block_until_ready(p.valid)

    t_two = _timeit(two_call, repeats)

    # (c) fully unfused: every stage its own jitted call, host round-trips
    # between them (fingerprinting / hashing / search tuned in isolation)
    binarize = jax.jit(
        lambda c, m1, m2: F.binarize_coeffs(c, fcfg, (m1, m2))[0])
    signatures = jax.jit(lambda b: L.signatures(b, mp, lcfg))
    hold5 = {"s": SI.init_index(lcfg, scfg.index)}

    def stage_chain():
        coeffs = np.asarray(E.block_coeffs(blockw, fcfg))
        bits = np.asarray(binarize(jnp.asarray(coeffs), med, mad))
        sigs = jnp.asarray(np.asarray(signatures(jnp.asarray(bits))))
        hold5["s"] = SI.insert(hold5["s"], sigs, ids, lcfg)
        p = SI.query(hold5["s"], sigs, ids, lcfg)
        jax.block_until_ready(p.valid)

    t_chain = _timeit(stage_chain, repeats)

    csv_line("e2e.step_fused", t_fused * 1e6, f"block={block} dispatches=1")
    csv_line("e2e.step_two_call", t_two * 1e6,
             f"speedup_fused={t_two / t_fused:.2f}x")
    csv_line("e2e.step_unfused_chain", t_chain * 1e6,
             f"speedup_fused={t_chain / t_fused:.2f}x dispatches=5")
    return {
        "block_fingerprints": block,
        "fused_ms": round(t_fused * 1e3, 4),
        "two_call_ms": round(t_two * 1e3, 4),
        "unfused_chain_ms": round(t_chain * 1e3, 4),
    }


# ---------------------------------------------------------------------------
# offline replay: the unified batch driver vs the legacy host loop
# ---------------------------------------------------------------------------


def _legacy_detect_loop(waveforms, cfg):
    """Benchmark-local copy of the pre-unification ``detect_events`` host
    loop (per-station stage chains, four blocking syncs per station) —
    the baseline the unified replay driver is measured against."""
    fcfg, lcfg, acfg = cfg.fingerprint, cfg.lsh, cfg.align
    station_events = []
    for st in range(waveforms.shape[0]):
        x = jnp.asarray(waveforms[st])
        bits, _ = F.fingerprints_from_waveform(
            x, fcfg, key=jax.random.PRNGKey(fcfg.stft_len + st))
        jax.block_until_ready(bits)
        mp = L.hash_mappings(fcfg.fp_dim, lcfg)
        sigs = L.signatures(bits, mp, lcfg)
        jax.block_until_ready(sigs)
        pairs = L.candidate_pairs(sigs, lcfg)
        if lcfg.occurrence_frac > 0:
            pairs, _ = L.occurrence_filter(pairs, bits.shape[0],
                                           lcfg.occurrence_frac)
        jax.block_until_ready(pairs.valid)
        merged = A.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = A.cluster_station(merged, acfg)
        jax.block_until_ready(events.valid)
        station_events.append(events)
    det = A.associate_network(station_events, acfg, waveforms.shape[0])
    jax.block_until_ready(det["valid"])
    return det


def offline_replay_points(duration_s: float, repeats: int = 3) -> dict:
    """Batch archive reprocessing: unified core vs legacy loop, 1/4/8
    stations. Both drivers run the identical detection semantics (the
    unified pair set is golden-pinned bit-exact against the legacy one),
    so the comparison is pure orchestration cost: one pooled fused
    dispatch per block vs per-station per-stage dispatches + syncs."""
    cfg = smoke_config()
    scfg = replay_config(cfg.lsh, block_fingerprints=64, n_buckets=2048)
    ds = make_dataset(SynthConfig(duration_s=duration_s, n_stations=8,
                                  n_sources=2, events_per_source=4,
                                  event_snr=3.0, seed=7))
    n_fp = cfg.fingerprint.n_fingerprints(ds.waveforms.shape[1])
    n_blocks = -(-n_fp // scfg.block_fingerprints)
    points = []
    for s in (1, 4, 8):
        wf = ds.waveforms[:s]

        def unified():
            return detect_events(wf, cfg, scfg=scfg)

        def legacy():
            return _legacy_detect_loop(wf, cfg)

        for fn in (unified, legacy):    # compile both before timing
            fn()
        t_uni = float(np.median([_wall(unified) for _ in range(repeats)]))
        t_leg = float(np.median([_wall(legacy) for _ in range(repeats)]))
        point = {
            "stations": s,
            "fingerprints": n_fp,
            "blocks": n_blocks,
            "unified_wall_ms": round(t_uni * 1e3, 2),
            "unified_blocks_per_s": round(n_blocks / max(t_uni, 1e-9), 2),
            "legacy_wall_ms": round(t_leg * 1e3, 2),
            "speedup_vs_legacy": round(t_leg / max(t_uni, 1e-9), 3),
        }
        csv_line(f"e2e.offline_replay_s{s}", t_uni * 1e6,
                 f"legacy={t_leg * 1e6:.0f}us "
                 f"speedup={point['speedup_vs_legacy']}x")
        points.append(point)
    return {
        "duration_s": duration_s,
        "block_fingerprints": scfg.block_fingerprints,
        "points": points,
        "speedup_vs_legacy_4st": next(
            p["speedup_vs_legacy"] for p in points if p["stations"] == 4),
    }


def _wall(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# end-to-end detector throughput + allocation behaviour
# ---------------------------------------------------------------------------


def interleaved_walls(cfg, scfg, wf, med_mad, n_chunks: int,
                      warmup: int) -> tuple[dict, dict, dict]:
    """Per-spec median ``push`` wall, measured round-robin per chunk.

    Also returns each spec's device-step/host-tail wall split (from the
    detector's own telemetry histograms) and the flagship 4-station
    pooled detector's ``metrics_snapshot()`` (ISSUE 6) — the structured
    telemetry view of the timed stream, embedded in ``BENCH_e2e.json``
    so a perf regression comes with its drop/quality/wall-histogram
    context attached."""
    dets = {k: _detector(cfg, scfg, k[0], k[1], med_mad) for k in SPECS}
    split = {k: np.array_split(wf[:k[0]], n_chunks, axis=1)
             for k in SPECS}
    for k, det in dets.items():
        for c in split[k][:warmup]:
            det.push(c)
    walls = {k: [] for k in SPECS}
    for i in range(warmup, n_chunks):
        for k, det in dets.items():
            t0 = time.perf_counter()
            det.push(split[k][i])
            walls[k].append(time.perf_counter() - t0)
    metrics = dets[(4, True)].metrics_snapshot()
    splits = {k: _wall_split(det) for k, det in dets.items()}
    return {k: float(np.median(w)) for k, w in walls.items()}, splits, \
        metrics


def memory_point(cfg, scfg, wf, med_mad, n_stations: int, fused: bool,
                 n_chunks: int, warmup: int) -> dict:
    """Retained-bytes + host-peak pass for one point (untimed).

    ``gc.collect()`` before each live-array snapshot: buffers abandoned
    by *earlier* benchmark phases (e.g. the offline-replay drivers) must
    not be collected mid-measurement and show up as a phantom negative
    delta on this point."""
    import gc
    det = _detector(cfg, scfg, n_stations, fused, med_mad)
    chunks = np.array_split(wf[:n_stations], n_chunks, axis=1)
    tracemalloc.start()
    for c in chunks[:warmup]:
        det.push(c)
    gc.collect()
    live0 = _live_bytes()
    for c in chunks[warmup:]:
        det.push(c)
    gc.collect()
    live_delta = _live_bytes() - live0
    _, host_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    timed = n_chunks - warmup
    return {
        "live_bytes_delta_per_chunk": int(live_delta / max(timed, 1)),
        "peak_host_mb": round(host_peak / 2**20, 3),
        "pairs": int(sum(st.stats.pairs for st in det.stations)),
    }


# ---------------------------------------------------------------------------
# emission A/B: device-side compaction + verify vs the dense pipe (ISSUE 8)
# ---------------------------------------------------------------------------


def emission_points(duration_s: float) -> dict:
    """Compaction on/off A/B at the paper-scale table count (t=100).

    Same latency-regime fingerprints, LSH widened to t=100 (the §6.3
    setting whose dense emission the compaction targets): one station's
    dense pipe is 100 × 4 × 8 = 3 200 slots per block; compacted it is
    ``max_pairs=128``. Both variants stream the same repeat-seeded
    waveforms through fused pooled detectors at 1 / 4 / 8 stations,
    interleaved per chunk (each sextet of detectors sees chunk k before
    any sees k+1); every point records the chunk p50 plus its device-
    step / host-tail split and the computed transfer bytes per block.
    """
    cfg = latency_config()
    cfg = dataclasses.replace(
        cfg, lsh=dataclasses.replace(cfg.lsh, n_tables=100))
    base = stream_latency_smoke_config()
    dense = dataclasses.replace(
        base, index=dataclasses.replace(base.index, bucket_cap=8))
    compact = dataclasses.replace(
        dense, max_pairs_per_block=128, verify_jaccard=True,
        index=dataclasses.replace(dense.index, bucket_cap=8,
                                  pk_slots=8192))
    ds = make_dataset(SynthConfig(duration_s=duration_s, n_stations=8,
                                  n_sources=2, events_per_source=4,
                                  event_snr=3.0, seed=7))
    wf = seed_repeating_events(np.asarray(ds.waveforms),
                               cfg.fingerprint.lag_samples)
    med_mad = frozen_smoke_stats(cfg, wf[0])
    n_chunks = int(wf.shape[1] // (dense.block_fingerprints
                                   * cfg.fingerprint.lag_samples))
    warmup = max(4, n_chunks // 10)

    specs = [(s, v) for s in (1, 4, 8) for v in ("dense", "compact")]
    scfgs = {"dense": dense, "compact": compact}
    dets = {k: _detector(cfg, scfgs[k[1]], k[0], True, med_mad)
            for k in specs}
    split = {k: np.array_split(wf[:k[0]], n_chunks, axis=1) for k in specs}
    for k, det in dets.items():
        for c in split[k][:warmup]:
            det.push(c)
    walls = {k: [] for k in specs}
    for i in range(warmup, n_chunks):
        for k, det in dets.items():
            t0 = time.perf_counter()
            det.push(split[k][i])
            walls[k].append(time.perf_counter() - t0)

    points = []
    for k in specs:
        s, variant = k
        det, scfg_v = dets[k], scfgs[variant]
        point = {"stations": s, "variant": variant,
                 "chunk_ms_p50": round(float(np.median(walls[k])) * 1e3, 4),
                 "pairs": int(sum(st.stats.pairs for st in det.stations)),
                 "overflow_pairs": int(det.telemetry.drop_breakdown()
                                       .get("overflow_pairs", 0)),
                 "pair_bytes_per_block":
                     pair_bytes_per_block(cfg.lsh, scfg_v)}
        point.update(_wall_split(det))
        csv_line(f"e2e.emission_s{s}_{variant}",
                 float(np.median(walls[k])) * 1e6,
                 f"pairs={point['pairs']} "
                 f"bytes/block={point['pair_bytes_per_block']} "
                 f"host_tail_p50={point['host_tail_ms_p50']}ms")
        points.append(point)

    def pt(s, v):
        return next(p for p in points if p["stations"] == s
                    and p["variant"] == v)

    return {
        "duration_s": duration_s,
        "n_tables": cfg.lsh.n_tables,
        "block_fingerprints": dense.block_fingerprints,
        "max_pairs_per_block": compact.max_pairs_per_block,
        "points": points,
        "pair_byte_reduction_t100": round(
            pt(1, "dense")["pair_bytes_per_block"]
            / pt(1, "compact")["pair_bytes_per_block"], 2),
        "host_tail_speedup_8st": round(
            pt(8, "dense")["host_tail_ms_p50"]
            / max(pt(8, "compact")["host_tail_ms_p50"], 1e-6), 3),
    }


# ---------------------------------------------------------------------------
# sharded station pool: device-count × stations scaling grid (ISSUE 10)
# ---------------------------------------------------------------------------


def sharded_child(spec: dict) -> dict:
    """One grid point, run inside a forced-device-count interpreter.

    Streams identical repeat-seeded noise through (a) the mesh-sharded
    pool and (b) the single-device ``vmap`` pool (``sharded=False``),
    interleaved per chunk so machine-noise phases hit both equally.
    Device-step percentiles are exact (raw telemetry samples, warmup
    excluded); the per-station pair counts feed the parent's bit-parity
    check — the two variants must agree exactly on clean data."""
    n_stations = int(spec["stations"])
    n_chunks = int(spec.get("chunks", 32))
    # warmup must cover stats freeze + the full-frame block compile +
    # the steady advance compile, for BOTH variants, or the first timed
    # chunk of one variant eats a compile the other got for free
    warmup = max(4, n_chunks // 8)
    cfg, base = latency_config(), stream_sharded_smoke_config()
    fcfg = cfg.fingerprint
    chunk = base.block_fingerprints * fcfg.lag_samples
    rng = np.random.default_rng(7)
    wf = rng.standard_normal((n_stations, n_chunks * chunk)) \
        .astype(np.float32)
    wf = seed_repeating_events(wf, fcfg.lag_samples)
    med_mad = frozen_smoke_stats(cfg, wf[0])
    chunks = np.array_split(wf, n_chunks, axis=1)

    variants = {
        "sharded": _detector(cfg, base, n_stations, True, med_mad),
        "baseline": _detector(
            cfg, dataclasses.replace(base, sharded=False), n_stations,
            True, med_mad),
    }
    for det in variants.values():
        for c in chunks[:warmup]:
            det.push(c)
        det.telemetry.raw_walls["fused_step"].clear()
    walls = {k: [] for k in variants}
    for c in chunks[warmup:]:
        for k, det in variants.items():
            t0 = time.perf_counter()
            det.push(c)
            walls[k].append(time.perf_counter() - t0)

    out = {"devices": jax.device_count(), "stations": n_stations,
           "chunks": n_chunks - warmup}
    for k, det in variants.items():
        steps = det.telemetry.raw_walls["fused_step"]
        out[k] = {
            "mesh_devices": int(det.mesh.devices.size) if det.mesh else 1,
            "pool_pad": det.pool_pad,
            "chunks_per_s": round(
                (n_chunks - warmup) / max(sum(walls[k]), 1e-9), 3),
            "device_step_ms_p50": round(
                float(np.percentile(steps, 50)) * 1e3, 4),
            "device_step_ms_p95": round(
                float(np.percentile(steps, 95)) * 1e3, 4),
            "pairs": [int(st.stats.pairs) for st in det.stations],
        }
    out["pair_parity"] = out["sharded"]["pairs"] == out["baseline"]["pairs"]
    out["speedup_vs_vmap"] = round(
        out["sharded"]["chunks_per_s"]
        / max(out["baseline"]["chunks_per_s"], 1e-9), 3)
    return out


def sharded_pool_points(quick: bool) -> dict:
    """Fan the (device count × stations) grid out over child
    interpreters: ``--xla_force_host_platform_device_count`` binds at
    backend init, so each device count needs a fresh process. The
    flagship point (8 stations × 8 devices, one station per device) is
    in both grids — the acceptance ratio reads from it."""
    root = pathlib.Path(__file__).resolve().parent.parent
    grid = [(2, 4), (8, 8)] if quick else \
        [(1, 8), (2, 8), (4, 8), (8, 8), (8, 16)]
    n_chunks = 24 if quick else 48
    points = []
    for devices, stations in grid:
        spec = {"devices": devices, "stations": stations,
                "chunks": n_chunks}
        env = dict(
            os.environ,
            PYTHONPATH=f"{root / 'src'}:{root}",
            JAX_PLATFORMS="cpu",
            XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_e2e",
             "--sharded-child", json.dumps(spec)],
            capture_output=True, text=True, env=env, cwd=root,
            timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(
                f"sharded child {spec} failed:\n{r.stdout}\n{r.stderr}")
        point = json.loads(r.stdout.strip().splitlines()[-1])
        assert point["pair_parity"], \
            f"sharded/vmap pair mismatch at {spec}: " \
            f"{point['sharded']['pairs']} vs {point['baseline']['pairs']}"
        csv_line(f"e2e.sharded_d{devices}_s{stations}",
                 1e6 / max(point["sharded"]["chunks_per_s"], 1e-9),
                 f"speedup_vs_vmap={point['speedup_vs_vmap']}x "
                 f"step_p50={point['sharded']['device_step_ms_p50']}ms")
        points.append(point)
    flagship = next((p for p in points
                     if p["devices"] == 8 and p["stations"] == 8), None)
    return {
        "block_fingerprints":
            stream_sharded_smoke_config().block_fingerprints,
        # forced host devices time-slice the physical cores: with fewer
        # cores than devices the parallel speedup is capped at
        # cores/1 — on a 1-core host the flagship ratio reads the pure
        # sharding overhead (≤ 1x), not the scaling curve
        "host_cores": len(os.sched_getaffinity(0)),
        "points": points,
        "speedup_8st_8dev":
            flagship["speedup_vs_vmap"] if flagship else None,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tier-1-safe smoke run (short stream)")
    ap.add_argument("--duration-s", type=float, default=0.0,
                    help="override stream length (0 = 240 normal/60 quick)")
    ap.add_argument("--step-repeats", type=int, default=0)
    ap.add_argument("--emit", action="store_true",
                    help="refresh only the emission A/B section of an "
                         "existing BENCH_e2e.json (make bench-emit)")
    ap.add_argument("--sharded", action="store_true",
                    help="refresh only the sharded_pool grid of an "
                         "existing BENCH_e2e.json (make bench-sharded)")
    ap.add_argument("--sharded-child", metavar="JSON",
                    help="internal: run one sharded grid point in this "
                         "(forced-device-count) interpreter and print "
                         "its JSON result")
    args = ap.parse_args(argv)

    if args.sharded_child:
        print(json.dumps(sharded_child(json.loads(args.sharded_child))))
        return None
    duration = args.duration_s or (60.0 if args.quick else 240.0)
    repeats = args.step_repeats or (50 if args.quick else 250)

    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    path = os.path.join(out_dir, "BENCH_e2e.json")

    if args.sharded:
        sharded = sharded_pool_points(args.quick)
        out = {"schema": SCHEMA}
        if os.path.exists(path):
            with open(path) as f:
                out = json.load(f)
            out["schema"] = SCHEMA
        out["sharded_pool"] = sharded
        out.setdefault("ratios", {})
        out["ratios"]["sharded_pool_speedup_8st_8dev"] = \
            sharded["speedup_8st_8dev"]
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {path} (sharded_pool section)")
        print(f"# sharded pool @8st x 8dev: "
              f"{sharded['speedup_8st_8dev']}x vs single-device vmap")
        return out

    if args.emit:
        emission = emission_points(duration)
        out = {"schema": SCHEMA}
        if os.path.exists(path):
            with open(path) as f:
                out = json.load(f)
            out["schema"] = SCHEMA
        out["emission"] = emission
        out.setdefault("ratios", {})
        out["ratios"]["emission_pair_byte_reduction_t100"] = \
            emission["pair_byte_reduction_t100"]
        out["ratios"]["emission_host_tail_speedup_8st"] = \
            emission["host_tail_speedup_8st"]
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {path} (emission section)")
        print(f"# emission bytes/block t=100: "
              f"{emission['pair_byte_reduction_t100']}x smaller; "
              f"host tail @8st: {emission['host_tail_speedup_8st']}x")
        return out

    cfg, scfg = latency_config(), stream_latency_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=duration, n_stations=8,
                                  n_sources=2, events_per_source=4,
                                  event_snr=3.0, seed=7))
    # grid-aligned repeating events: streaming points emit real pairs,
    # so the timed path includes actual emission/host-tail work (the v2
    # points all recorded pairs: 0)
    wf = seed_repeating_events(np.asarray(ds.waveforms),
                               cfg.fingerprint.lag_samples)
    med_mad = frozen_smoke_stats(cfg, wf[0])

    # one chunk per block advance: the per-arrival serving cadence
    n_chunks = int(wf.shape[1]
                   // (scfg.block_fingerprints
                       * cfg.fingerprint.lag_samples))
    warmup = max(4, n_chunks // 10)

    step = step_points(cfg, scfg, repeats)
    replay = offline_replay_points(duration)
    emission = emission_points(duration)
    sharded = sharded_pool_points(args.quick)
    walls, splits, metrics = interleaved_walls(cfg, scfg, wf, med_mad,
                                               n_chunks, warmup)
    points = []
    for k in SPECS:
        n_stations, fused = k
        point = {"stations": n_stations, "fused": fused,
                 "chunks": n_chunks - warmup,
                 "chunk_ms_p50": round(walls[k] * 1e3, 4),
                 "chunks_per_s": round(1.0 / max(walls[k], 1e-9), 2),
                 "pair_bytes_per_block":
                     pair_bytes_per_block(cfg.lsh, scfg)}
        point.update(splits[k])
        point.update(memory_point(cfg, scfg, wf, med_mad, n_stations,
                                  fused, n_chunks, warmup))
        csv_line(f"e2e.push_s{n_stations}_{'fused' if fused else 'unfused'}",
                 walls[k] * 1e6,
                 f"chunks_per_s={point['chunks_per_s']} "
                 f"live_delta={point['live_bytes_delta_per_chunk']}B/chunk")
        points.append(point)

    ratios = {
        "fused_speedup_vs_unfused_chain": round(
            step["unfused_chain_ms"] / step["fused_ms"], 3),
        "fused_speedup_vs_two_call": round(
            step["two_call_ms"] / step["fused_ms"], 3),
        "e2e_fused_speedup_vs_unfused_1st": round(
            walls[(1, False)] / walls[(1, True)], 3),
        "pool_wall_x_4st_vs_1st": round(
            walls[(4, True)] / walls[(1, True)], 3),
        "pool_wall_x_8st_vs_1st": round(
            walls[(8, True)] / walls[(1, True)], 3),
        "offline_replay_speedup_vs_legacy_4st":
            replay["speedup_vs_legacy_4st"],
        "emission_pair_byte_reduction_t100":
            emission["pair_byte_reduction_t100"],
        "emission_host_tail_speedup_8st":
            emission["host_tail_speedup_8st"],
        "sharded_pool_speedup_8st_8dev": sharded["speedup_8st_8dev"],
    }
    out = {
        "schema": SCHEMA,
        "config_hash": config_hash(cfg, scfg),
        "backend": jax.default_backend(),
        "quick": bool(args.quick),
        "duration_s": duration,
        "step": step,
        "points": points,
        "offline_replay": replay,
        "emission": emission,
        "sharded_pool": sharded,
        "ratios": ratios,
        "metrics": metrics,
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    print(f"# fused vs unfused chain: "
          f"{ratios['fused_speedup_vs_unfused_chain']}x; "
          f"8-station pool wall: {ratios['pool_wall_x_8st_vs_1st']}x "
          f"1-station; offline replay vs legacy loop @4st: "
          f"{replay['speedup_vs_legacy_4st']}x; emission pipe @t=100: "
          f"{emission['pair_byte_reduction_t100']}x fewer bytes/block; "
          f"sharded pool @8st x 8dev: {sharded['speedup_8st_8dev']}x")
    return out


if __name__ == "__main__":
    main()
