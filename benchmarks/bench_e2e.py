"""End-to-end streaming hot-path benchmark (ISSUE 3): BENCH_e2e.json.

Measures the fused single-dispatch chunk step against the unfused
pipeline at the real-time **latency configuration**
(``configs.fast_seismic.latency_config``: short blocks for low alert
latency — the regime where per-stage dispatch overhead, not FLOPs, bounds
throughput), at three granularities:

* **step**: steady-state per-block wall of (a) the fused single dispatch,
  (b) the PR-1/2 two-call chain (``block_coeffs`` + ``stream_step``), and
  (c) the fully unfused five-stage chain — fingerprint, binarize,
  signatures, insert, query as separate jitted calls with host
  round-trips between them (the "tuned in isolation" pipeline of the
  paper's motivation, which the fused step replaces).
* **e2e**: ``StreamingDetector.push`` chunks/sec, fused vs unfused at
  1 station and the vmapped station pool at 1 / 4 / 8 stations. All
  points are timed **interleaved** (every detector sees chunk k before
  any sees chunk k+1) and summarized by median per-push wall, so
  shared-machine noise phases hit every point equally instead of
  skewing whichever point they coincide with.
* **memory**: retained device bytes per chunk after warmup
  (``jax.live_arrays`` delta — 0 means every steady-state buffer is a
  donated in-place reuse) and peak host MB (tracemalloc), from a
  separate per-point pass.

Schema-stable output: ``BENCH_e2e.json`` with ``schema: "bench-e2e/v1"``,
a config hash, per-point chunks/sec, and the headline ratios
(fused speedup vs the unfused chain; 4-/8-station pool wall vs
1-station). ``--quick`` shrinks the stream for the tier-1-safe smoke
invocation (``make bench-smoke`` / the slow-marked pytest guard).
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, frozen_smoke_stats
from repro.configs.fast_seismic import (latency_config,
                                        stream_latency_smoke_config)
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import engine as E
from repro.stream import fused as FU
from repro.stream import index as SI
from repro.stream.engine import StreamingDetector

SCHEMA = "bench-e2e/v1"

# (stations, fused) points; (1, False) is the unfused e2e reference
SPECS = [(1, True), (1, False), (4, True), (8, True)]


def config_hash(cfg, scfg) -> str:
    blob = json.dumps(
        {"cfg": dataclasses.asdict(cfg), "scfg": dataclasses.asdict(scfg)},
        sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _live_bytes() -> int:
    return sum(a.nbytes for a in jax.live_arrays())


def _timeit(fn, repeats: int, batches: int = 5) -> float:
    """Min-of-batches per-call seconds (robust to shared-machine noise:
    the minimum batch is the least-perturbed measurement)."""
    fn()
    fn()
    per = max(1, repeats // batches)
    best = float("inf")
    for _ in range(batches):
        t0 = time.perf_counter()
        for _ in range(per):
            fn()
        best = min(best, (time.perf_counter() - t0) / per)
    return best


def _detector(cfg, scfg, n_stations, fused, med_mad):
    scfg = dataclasses.replace(scfg, fused=fused, pooled=fused)
    return StreamingDetector(cfg, scfg, n_stations=n_stations,
                             med_mad=med_mad)


# ---------------------------------------------------------------------------
# step-level: one block through each pipeline shape
# ---------------------------------------------------------------------------


def step_points(cfg, scfg, repeats: int) -> dict:
    fcfg, lcfg = cfg.fingerprint, cfg.lsh
    block = scfg.block_fingerprints
    rng = np.random.default_rng(0)
    med = jnp.zeros(fcfg.n_coeff)
    mad = jnp.ones(fcfg.n_coeff)
    mp = L.hash_mappings(fcfg.fp_dim, lcfg)
    blockw = jnp.asarray(
        rng.standard_normal(fcfg.block_samples(block)).astype(np.float32))
    adv = blockw[-block * fcfg.lag_samples:]
    ids = jnp.arange(block, dtype=jnp.int32)
    vmask = jnp.ones(block, bool)

    # (a) fused single dispatch (donated state, device halo)
    hold = {"s": FU.init_state(SI.init_index(lcfg, scfg.index),
                               fcfg.halo_samples, med, mad)}

    def fused_step():
        hold["s"], p, _ = FU.step_advance(hold["s"], adv, mp, jnp.int32(0),
                                          fcfg, lcfg, 0)
        jax.block_until_ready(p.valid)

    t_fused = _timeit(fused_step, repeats)

    # (b) the PR-1/2 two-call chain
    hold2 = {"s": SI.init_index(lcfg, scfg.index)}

    def two_call():
        coeffs = E.block_coeffs(blockw, fcfg)
        hold2["s"], p, _ = E.stream_step(hold2["s"], coeffs, med, mad, mp,
                                         jnp.int32(0), vmask, fcfg, lcfg, 0)
        jax.block_until_ready(p.valid)

    t_two = _timeit(two_call, repeats)

    # (c) fully unfused: every stage its own jitted call, host round-trips
    # between them (fingerprinting / hashing / search tuned in isolation)
    binarize = jax.jit(
        lambda c, m1, m2: F.binarize_coeffs(c, fcfg, (m1, m2))[0])
    signatures = jax.jit(lambda b: L.signatures(b, mp, lcfg))
    hold5 = {"s": SI.init_index(lcfg, scfg.index)}

    def stage_chain():
        coeffs = np.asarray(E.block_coeffs(blockw, fcfg))
        bits = np.asarray(binarize(jnp.asarray(coeffs), med, mad))
        sigs = jnp.asarray(np.asarray(signatures(jnp.asarray(bits))))
        hold5["s"] = SI.insert(hold5["s"], sigs, ids, lcfg)
        p = SI.query(hold5["s"], sigs, ids, lcfg)
        jax.block_until_ready(p.valid)

    t_chain = _timeit(stage_chain, repeats)

    csv_line("e2e.step_fused", t_fused * 1e6, f"block={block} dispatches=1")
    csv_line("e2e.step_two_call", t_two * 1e6,
             f"speedup_fused={t_two / t_fused:.2f}x")
    csv_line("e2e.step_unfused_chain", t_chain * 1e6,
             f"speedup_fused={t_chain / t_fused:.2f}x dispatches=5")
    return {
        "block_fingerprints": block,
        "fused_ms": round(t_fused * 1e3, 4),
        "two_call_ms": round(t_two * 1e3, 4),
        "unfused_chain_ms": round(t_chain * 1e3, 4),
    }


# ---------------------------------------------------------------------------
# end-to-end detector throughput + allocation behaviour
# ---------------------------------------------------------------------------


def interleaved_walls(cfg, scfg, ds, med_mad, n_chunks: int,
                      warmup: int) -> dict:
    """Per-spec median ``push`` wall, measured round-robin per chunk."""
    dets = {k: _detector(cfg, scfg, k[0], k[1], med_mad) for k in SPECS}
    split = {k: np.array_split(ds.waveforms[:k[0]], n_chunks, axis=1)
             for k in SPECS}
    for k, det in dets.items():
        for c in split[k][:warmup]:
            det.push(c)
    walls = {k: [] for k in SPECS}
    for i in range(warmup, n_chunks):
        for k, det in dets.items():
            t0 = time.perf_counter()
            det.push(split[k][i])
            walls[k].append(time.perf_counter() - t0)
    return {k: float(np.median(w)) for k, w in walls.items()}


def memory_point(cfg, scfg, ds, med_mad, n_stations: int, fused: bool,
                 n_chunks: int, warmup: int) -> dict:
    """Retained-bytes + host-peak pass for one point (untimed)."""
    det = _detector(cfg, scfg, n_stations, fused, med_mad)
    chunks = np.array_split(ds.waveforms[:n_stations], n_chunks, axis=1)
    tracemalloc.start()
    for c in chunks[:warmup]:
        det.push(c)
    live0 = _live_bytes()
    for c in chunks[warmup:]:
        det.push(c)
    live_delta = _live_bytes() - live0
    _, host_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    timed = n_chunks - warmup
    return {
        "live_bytes_delta_per_chunk": int(live_delta / max(timed, 1)),
        "peak_host_mb": round(host_peak / 2**20, 3),
        "pairs": int(sum(st.stats.pairs for st in det.stations)),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tier-1-safe smoke run (short stream)")
    ap.add_argument("--duration-s", type=float, default=0.0,
                    help="override stream length (0 = 240 normal/60 quick)")
    ap.add_argument("--step-repeats", type=int, default=0)
    args = ap.parse_args(argv)
    duration = args.duration_s or (60.0 if args.quick else 240.0)
    repeats = args.step_repeats or (50 if args.quick else 250)

    cfg, scfg = latency_config(), stream_latency_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=duration, n_stations=8,
                                  n_sources=2, events_per_source=4,
                                  event_snr=3.0, seed=7))
    med_mad = frozen_smoke_stats(cfg, ds.waveforms[0])

    # one chunk per block advance: the per-arrival serving cadence
    n_chunks = int(ds.waveforms.shape[1]
                   // (scfg.block_fingerprints
                       * cfg.fingerprint.lag_samples))
    warmup = max(4, n_chunks // 10)

    step = step_points(cfg, scfg, repeats)
    walls = interleaved_walls(cfg, scfg, ds, med_mad, n_chunks, warmup)
    points = []
    for k in SPECS:
        n_stations, fused = k
        point = {"stations": n_stations, "fused": fused,
                 "chunks": n_chunks - warmup,
                 "chunk_ms_p50": round(walls[k] * 1e3, 4),
                 "chunks_per_s": round(1.0 / max(walls[k], 1e-9), 2)}
        point.update(memory_point(cfg, scfg, ds, med_mad, n_stations,
                                  fused, n_chunks, warmup))
        csv_line(f"e2e.push_s{n_stations}_{'fused' if fused else 'unfused'}",
                 walls[k] * 1e6,
                 f"chunks_per_s={point['chunks_per_s']} "
                 f"live_delta={point['live_bytes_delta_per_chunk']}B/chunk")
        points.append(point)

    ratios = {
        "fused_speedup_vs_unfused_chain": round(
            step["unfused_chain_ms"] / step["fused_ms"], 3),
        "fused_speedup_vs_two_call": round(
            step["two_call_ms"] / step["fused_ms"], 3),
        "e2e_fused_speedup_vs_unfused_1st": round(
            walls[(1, False)] / walls[(1, True)], 3),
        "pool_wall_x_4st_vs_1st": round(
            walls[(4, True)] / walls[(1, True)], 3),
        "pool_wall_x_8st_vs_1st": round(
            walls[(8, True)] / walls[(1, True)], 3),
    }
    out = {
        "schema": SCHEMA,
        "config_hash": config_hash(cfg, scfg),
        "backend": jax.default_backend(),
        "quick": bool(args.quick),
        "duration_s": duration,
        "step": step,
        "points": points,
        "ratios": ratios,
    }
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    path = os.path.join(out_dir, "BENCH_e2e.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    print(f"# fused vs unfused chain: "
          f"{ratios['fused_speedup_vs_unfused_chain']}x; "
          f"8-station pool wall: {ratios['pool_wall_x_8st_vs_1st']}x "
          f"1-station")
    return out


if __name__ == "__main__":
    main()
