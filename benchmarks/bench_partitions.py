"""Paper Figure 13: partitioned search — memory vs runtime trade-off."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (bench_lsh_config, csv_line,
                               station_fingerprints, timed)
from repro.core import lsh as L


def main():
    ds, fcfg, bits, packed = station_fingerprints(station=1)
    n = (bits.shape[0] // 8) * 8
    bits = bits[:n]
    lcfg = bench_lsh_config(fcfg, occurrence_frac=0.0)
    rows = []
    base_pairs = None
    for parts in (1, 2, 4, 8):
        if parts == 1:
            def run():
                return [L.search(bits, lcfg)[0]]
        else:
            def run():
                return L.partitioned_search(bits, lcfg, parts)[0]
        t, out = timed(run, repeats=2)
        total = sum(int(np.asarray(p.count())) for p in out)
        if base_pairs is None:
            base_pairs = total
        # working set ∝ sort keys per block (the paper's in-memory tables)
        block = 2 * (n // parts) if parts > 1 else n
        ws_bytes = block * lcfg.n_tables * 8 * lcfg.bucket_cap
        rows.append((parts, t, ws_bytes, total))
        csv_line(f"partitions.p{parts}", t * 1e6,
                 f"working_set_mb={ws_bytes/1e6:.0f} pairs={total} "
                 f"pairs_match_base={total == base_pairs}")
    return rows


if __name__ == "__main__":
    main()
