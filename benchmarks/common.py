"""Shared benchmark substrate: CPU-scale synthetic dataset + timing."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AlignConfig, DetectConfig, FingerprintConfig,
                        LSHConfig, SynthConfig, make_dataset)
from repro.core import fingerprint as F
from repro.core import lsh as L


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Median wall seconds over repeats (jit warm-up excluded)."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


@functools.lru_cache(maxsize=4)
def bench_dataset(duration_s: float = 600.0, with_noise: bool = True,
                  with_hum: bool = False, seed: int = 3):
    return make_dataset(SynthConfig(
        duration_s=duration_s, n_stations=3, n_sources=3,
        events_per_source=4, event_snr=3.0,
        repeating_noise_stations=(0,) if with_noise else (),
        repeating_noise_rate_hz=0.25,
        hum_stations=(1,) if with_hum else (), seed=seed))


def bench_fp_config(**over) -> FingerprintConfig:
    base = dict(img_time=32, img_hop=4, top_k=200, mad_sample_rate=1.0)
    base.update(over)
    return FingerprintConfig(**base)


def bench_lsh_config(fcfg: FingerprintConfig, **over) -> LSHConfig:
    base = dict(n_tables=100, n_funcs=4, n_matches=2, bucket_cap=8,
                min_dt=fcfg.overlap_fingerprints, occurrence_frac=0.0)
    base.update(over)
    return LSHConfig(**base)


@functools.lru_cache(maxsize=8)
def station_fingerprints(station: int = 1, duration_s: float = 600.0,
                         with_noise: bool = True, img_time: int = 32,
                         band: tuple = (3.0, 20.0)):
    """Cached fingerprints for one station of the bench dataset."""
    ds = bench_dataset(duration_s, with_noise)
    fcfg = bench_fp_config(img_time=img_time, band_lo_hz=band[0],
                           band_hi_hz=band[1])
    bits, packed = F.fingerprints_from_waveform(
        jnp.asarray(ds.waveforms[station]), fcfg)
    return ds, fcfg, bits, packed


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line)
    return line


# ---------------------------------------------------------------------------
# streaming-bench substrate (shared by bench_stream / bench_e2e; the chunk
# ingest loop itself is repro.stream.engine.ingest_chunks, also used by
# launch/serve_detect)
# ---------------------------------------------------------------------------


def stream_smoke_configs(bounded: bool = False):
    """(DetectConfig, StreamConfig) for streaming benchmarks — built once,
    not re-imported per bench mode / stream multiplier."""
    from repro.configs.fast_seismic import (smoke_config,
                                            stream_bounded_smoke_config,
                                            stream_smoke_config)
    scfg = stream_bounded_smoke_config() if bounded else stream_smoke_config()
    return smoke_config(), scfg


def stream_smoke_dataset(duration_s: float = 600.0, n_stations: int = 1, *,
                         seed: int = 7, events_per_source: int = 4):
    return make_dataset(SynthConfig(
        duration_s=duration_s, n_stations=n_stations, n_sources=2,
        events_per_source=events_per_source, event_snr=3.0, seed=seed))


def seed_repeating_events(waveforms: np.ndarray, lag_samples: int, *,
                          amp: float = 6.0, period_samples: int = 400,
                          start_sample: int = 500) -> np.ndarray:
    """Inject grid-aligned repeating bursts so pair emission is nonzero.

    The synthetic sources place events at arbitrary sample offsets, but a
    repeat only hash-collides when it lands at the same phase of the
    fingerprint frame grid — at the tiny latency-benchmark fingerprints a
    sub-lag misalignment shifts the whole spectral image, so the e2e
    streaming benchmarks historically recorded ``pairs: 0`` and never
    exercised the emission/host-tail path they claim to measure. This
    adds the Figure-7 three-spike template at offsets snapped to
    ``lag_samples``, on every station: sample-aligned repeats with
    Jaccard high enough to pair under the latency LSH config. Returns a
    seeded copy; period/start are in samples and both snap to the grid.
    """
    from repro.core.synth import _repeating_noise_template
    wf = np.array(waveforms, np.float32, copy=True)
    rng = np.random.default_rng(11)
    tpl = _repeating_noise_template(
        rng, SynthConfig(duration_s=1.0)) * amp
    period = max(lag_samples, (period_samples // lag_samples) * lag_samples)
    start = (start_sample // lag_samples) * lag_samples
    for st in range(wf.shape[0]):
        for i0 in range(start, wf.shape[1] - tpl.size, period):
            wf[st, i0:i0 + tpl.size] += tpl
    return wf


def frozen_smoke_stats(cfg, waveform) -> tuple[np.ndarray, np.ndarray]:
    """Offline §5.2 median/MAD for a trace (pre-frozen detector stats, so
    benches measure the steady state rather than the warmup path)."""
    med, mad = F.mad_stats(
        F.coeffs_from_waveform(jnp.asarray(waveform), cfg.fingerprint),
        1.0, jax.random.PRNGKey(0))
    return np.asarray(med), np.asarray(mad)
