"""Paper Figure 10 / Table 5: cumulative factor analysis of the pipeline.

Baseline (MinHash k=4 m=5, no filters, full MAD) → + occurrence filter →
+ more hash funcs & lower threshold (k8/m2-analog: k6/m1 at CPU scale) →
+ locality Min-Max hash → + MAD sampling. Reports per-stage wall time and
output size after each cumulative optimization (synthetic station data).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dataset, bench_fp_config, csv_line
from repro.core import align as A
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.align import AlignConfig


def run_variant(ds, fcfg, lcfg, use_minmax, station=0):
    """Fingerprint → signatures → search → cluster; stage wall times."""
    x = jnp.asarray(ds.waveforms[station])
    t0 = time.perf_counter()
    bits, _ = F.fingerprints_from_waveform(x, fcfg)
    jax.block_until_ready(bits)
    t1 = time.perf_counter()
    mp = L.hash_mappings(fcfg.fp_dim, lcfg)
    sigs = L.signatures(bits, mp, lcfg)
    jax.block_until_ready(sigs)
    t2 = time.perf_counter()
    pairs = L.candidate_pairs(sigs, lcfg)
    if lcfg.occurrence_frac > 0:
        pairs, _ = L.occurrence_filter(pairs, bits.shape[0],
                                       lcfg.occurrence_frac)
    jax.block_until_ready(pairs.valid)
    t3 = time.perf_counter()
    ev = A.cluster_station(pairs, AlignConfig(min_cluster_size=1,
                                              min_cluster_sim=4))
    jax.block_until_ready(ev.valid)
    t4 = time.perf_counter()
    return {"fingerprint_s": t1 - t0, "hashgen_s": t2 - t1,
            "search_s": t3 - t2, "align_s": t4 - t3,
            "total_s": t4 - t0, "pairs": int(pairs.count()),
            "events": int(ev.count())}


def main():
    ds = bench_dataset(duration_s=600.0, with_noise=True)
    fp_full = bench_fp_config(mad_sample_rate=1.0)
    fp_sampled = bench_fp_config(mad_sample_rate=0.1)

    variants = [
        ("baseline(minhash,k4m5,no-filters)", fp_full,
         dict(n_funcs=4, n_matches=5, use_minmax=False,
              occurrence_frac=0.0)),
        ("+occur_filter", fp_full,
         dict(n_funcs=4, n_matches=5, use_minmax=False,
              occurrence_frac=0.05)),
        ("+increase_funcs(k6m1)", fp_full,
         dict(n_funcs=6, n_matches=1, use_minmax=False,
              occurrence_frac=0.05)),
        ("+minmax_hash", fp_full,
         dict(n_funcs=6, n_matches=1, use_minmax=True,
              occurrence_frac=0.05)),
        ("+mad_sample(10%)", fp_sampled,
         dict(n_funcs=6, n_matches=1, use_minmax=True,
              occurrence_frac=0.05)),
    ]
    rows = []
    base_total = None
    for name, fcfg, over in variants:
        lcfg = L.LSHConfig(n_tables=100, bucket_cap=8,
                           min_dt=fcfg.overlap_fingerprints, **over)
        # warm-up then measure
        run_variant(ds, fcfg, lcfg, over["use_minmax"])
        r = run_variant(ds, fcfg, lcfg, over["use_minmax"])
        if base_total is None:
            base_total = r["total_s"]
        speedup = base_total / r["total_s"]
        rows.append((name, r, speedup))
        csv_line(f"factor.{name}", r["total_s"] * 1e6,
                 f"speedup={speedup:.2f}x pairs={r['pairs']} "
                 f"fp={r['fingerprint_s']:.2f}s hash={r['hashgen_s']:.2f}s "
                 f"search={r['search_s']:.2f}s align={r['align_s']:.2f}s")
    return rows


if __name__ == "__main__":
    main()
