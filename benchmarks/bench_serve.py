"""Serving-tier benchmark (ISSUE 7): BENCH_serve.json.

Measures the concurrent query service built on the pooled fused hot path
(``launch/serve_detect.ServeDetectEngine``) at the real-time latency
configuration, the same regime as ``bench_e2e``:

* **closed-loop load points**: N concurrent clients, each resubmitting a
  fresh query window the moment its previous request completes, against
  a corpus pool at 1 / 4 / 8 stations. Per point: sustained QPS, p50/p99
  request latency with the admission-queue wait split out from in-slot
  service time, and the shed rate at the bounded queue (overload answers
  ``rejected`` immediately instead of queueing without bound).
* **overload determinism**: a burst of B > max_queue submissions against
  an idle engine must shed exactly B - max_queue — the admission bound
  is a contract, not a heuristic (also pinned by ``tests/test_serve.py``).
* **interleaved serving**: ingest and query ticks sharing one thread
  (``ServeSession``) — corpus chunks keep growing the pool while
  requests arrive spread over the stream, with the serving snapshot
  refreshed at the configured cadence.

Schema-stable output: ``BENCH_serve.json`` with ``schema:
"bench-serve/v1"``, a config hash, and the detector's
``metrics_snapshot()`` (whose ``serve`` section is fed by the engines
through the shared telemetry registry). ``--quick`` shrinks the corpus
and client rounds for the tier-1-safe smoke invocation
(``make bench-smoke`` / the slow-marked pytest guard).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import csv_line, frozen_smoke_stats
from benchmarks.bench_e2e import config_hash
from repro.configs.fast_seismic import (latency_config,
                                        stream_latency_smoke_config)
from repro.core.synth import SynthConfig, make_dataset
from repro.launch.serve_detect import (QueryRequest, ServeDetectEngine,
                                       ServeSession)
from repro.stream.engine import StreamingDetector, ingest_chunks

SCHEMA = "bench-serve/v1"

STATIONS = (1, 4, 8)
CLIENTS = (4, 16, 64)       # ≥3 concurrency levels per station count
N_SLOTS = 4
MAX_QUEUE = 8               # small enough that 64 clients shed


def _windows(waveform: np.ndarray, n: int, win: int) -> list[np.ndarray]:
    rng = np.random.default_rng(11)
    starts = rng.integers(0, waveform.size - win, size=n)
    return [waveform[s: s + win] for s in starts]


def closed_loop(eng: ServeDetectEngine, windows: list[np.ndarray],
                clients: int, rounds: int) -> tuple[list, float]:
    """N closed-loop clients: each resubmits the moment its in-flight
    request completes (served *or* shed — a shed completes instantly),
    until every client has issued ``rounds`` requests. Completions are
    observed once per tick, so a shed client re-offers next tick against
    a queue the tick just drained."""
    reqs: list[QueryRequest] = []
    inflight: list[QueryRequest] = [None] * clients
    issued = [0] * clients

    def launch(c: int) -> None:
        r = QueryRequest(rid=len(reqs),
                         window=windows[len(reqs) % len(windows)])
        reqs.append(r)
        issued[c] += 1
        inflight[c] = r
        eng.submit(r)

    t0 = time.perf_counter()
    for c in range(clients):    # the arrival burst
        launch(c)
    while True:
        if eng.pending():
            eng.tick()
        relaunched = False
        for c in range(clients):
            if inflight[c].done and issued[c] < rounds:
                launch(c)
                relaunched = True
        if not relaunched and not eng.pending():
            break
    return reqs, time.perf_counter() - t0


def load_points(cfg, scfg, ds, med_mad, n_chunks: int, win: int,
                rounds: int) -> tuple[list, dict]:
    """The QPS/latency/shed grid: stations × concurrency levels."""
    points = []
    metrics = None
    for s in STATIONS:
        det = StreamingDetector(cfg, scfg, n_stations=s, med_mad=med_mad)
        ingest_chunks(det, ds.waveforms[:s], n_chunks=n_chunks)
        det.flush()
        windows = _windows(ds.waveforms[0], 32, win)
        warm = ServeDetectEngine.from_detector(det, n_slots=N_SLOTS,
                                               max_queue=MAX_QUEUE)
        warm.run([QueryRequest(rid=0, window=windows[0])])  # compile
        for clients in CLIENTS:
            eng = ServeDetectEngine.from_detector(
                det, n_slots=N_SLOTS, max_queue=MAX_QUEUE)
            reqs, wall = closed_loop(eng, windows, clients, rounds)
            stats = eng.summary(reqs, wall)
            point = {
                "stations": s,
                "clients": clients,
                "slots": N_SLOTS,
                "max_queue": MAX_QUEUE,
                "requests": stats["requests"],
                "served": stats["served"],
                "shed": stats["shed"],
                "shed_rate": round(
                    stats["shed"] / max(stats["requests"], 1), 4),
                "wall_s": stats["wall_s"],
                "qps": stats["requests_per_s"],
                "ticks": stats["ticks"],
                "dispatches": stats["dispatches"],
                "latency_ms": {"p50": stats["latency_ms_p50"],
                               "p99": stats["latency_ms_p99"]},
                "queue_wait_ms": {"p50": stats["queue_wait_ms_p50"],
                                  "p99": stats["queue_wait_ms_p99"]},
                "service_ms": {"p50": stats["service_ms_p50"],
                               "p99": stats["service_ms_p99"]},
            }
            csv_line(f"serve.s{s}_c{clients}", wall * 1e6,
                     f"qps={point['qps']} shed_rate={point['shed_rate']} "
                     f"p99={point['latency_ms']['p99']}ms")
            points.append(point)
        if s == 4:      # flagship point carries the telemetry view
            metrics = det.metrics_snapshot()
    return points, metrics


def overload(det, windows: list[np.ndarray], burst: int) -> dict:
    """Deterministic shedding: an idle engine offered ``burst`` requests
    before any tick accepts exactly ``max_queue`` and sheds the rest —
    then serves everything it accepted."""
    eng = ServeDetectEngine.from_detector(det, n_slots=N_SLOTS,
                                          max_queue=MAX_QUEUE)
    reqs = [QueryRequest(rid=i, window=windows[i % len(windows)])
            for i in range(burst)]
    for r in reqs:
        eng.submit(r)
    shed = sum(1 for r in reqs if r.outcome == "rejected")
    eng.drain()
    served = sum(1 for r in reqs if r.outcome == "served")
    out = {
        "burst": burst,
        "max_queue": MAX_QUEUE,
        "accepted": burst - shed,
        "served": served,
        "shed": shed,
        "deterministic": shed == max(0, burst - MAX_QUEUE)
        and served == min(burst, MAX_QUEUE),
    }
    csv_line("serve.overload", shed, f"burst={burst} "
             f"deterministic={out['deterministic']}")
    return out


def interleaved_point(cfg, scfg, ds, med_mad, n_chunks: int, win: int,
                      n_requests: int) -> dict:
    """Ingest + serve on one thread: requests arrive spread over the
    chunk stream and are answered against the refreshed pool snapshot."""
    s = 4
    det = StreamingDetector(cfg, scfg, n_stations=s, med_mad=med_mad)
    eng = ServeDetectEngine(cfg, scfg, n_slots=N_SLOTS,
                            max_queue=MAX_QUEUE, telemetry=det.telemetry)
    session = ServeSession(det, eng, refresh_every_chunks=2)
    windows = _windows(ds.waveforms[0], 32, win)
    reqs = [QueryRequest(rid=i, window=windows[i % len(windows)])
            for i in range(n_requests)]
    arrival = [i * n_chunks // max(n_requests, 1) for i in range(n_requests)]
    nxt = [0]

    def on_chunk(ci: int) -> None:
        while nxt[0] < len(reqs) and arrival[nxt[0]] <= ci:
            session.submit(reqs[nxt[0]])
            nxt[0] += 1
        session.after_push()

    t0 = time.perf_counter()
    ingest_chunks(det, ds.waveforms[:s], n_chunks=n_chunks,
                  on_chunk=on_chunk)
    served_live = sum(1 for r in reqs if r.outcome == "served")
    session.finish()
    wall = time.perf_counter() - t0
    stats = eng.summary(reqs, wall)
    out = {
        "stations": s,
        "requests": n_requests,
        "served": stats["served"],
        "served_during_ingest": served_live,
        "shed": stats["shed"],
        "refreshes": session.refreshes,
        "wall_s": round(wall, 3),
        "qps": stats["requests_per_s"],
        "latency_ms": {"p50": stats["latency_ms_p50"],
                       "p99": stats["latency_ms_p99"]},
        "queue_wait_ms": {"p50": stats["queue_wait_ms_p50"],
                          "p99": stats["queue_wait_ms_p99"]},
    }
    csv_line("serve.interleaved", wall * 1e6,
             f"served_live={served_live}/{n_requests} "
             f"refreshes={session.refreshes}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tier-1-safe smoke run (short corpus, few rounds)")
    ap.add_argument("--duration-s", type=float, default=0.0,
                    help="override corpus length (0 = 120 normal/45 quick)")
    ap.add_argument("--rounds", type=int, default=0,
                    help="requests per closed-loop client (0 = 6/2 quick)")
    args = ap.parse_args(argv)
    duration = args.duration_s or (45.0 if args.quick else 120.0)
    rounds = args.rounds or (2 if args.quick else 6)

    cfg, scfg = latency_config(), stream_latency_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=duration, n_stations=8,
                                  n_sources=2, events_per_source=4,
                                  event_snr=3.0, seed=7))
    med_mad = frozen_smoke_stats(cfg, ds.waveforms[0])
    win = 8 * int(cfg.fingerprint.fs)       # 8 s → two blocks per request
    n_chunks = max(4, int(ds.waveforms.shape[1]
                          // (scfg.block_fingerprints
                              * cfg.fingerprint.lag_samples) // 4))

    points, metrics = load_points(cfg, scfg, ds, med_mad, n_chunks, win,
                                  rounds)
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    ingest_chunks(det, ds.waveforms[:1], n_chunks=n_chunks)
    det.flush()
    ovl = overload(det, _windows(ds.waveforms[0], 8, win),
                   burst=MAX_QUEUE + 12)
    inter = interleaved_point(cfg, scfg, ds, med_mad, n_chunks, win,
                              n_requests=8 if args.quick else 24)

    out = {
        "schema": SCHEMA,
        "config_hash": config_hash(cfg, scfg),
        "backend": jax.default_backend(),
        "quick": bool(args.quick),
        "duration_s": duration,
        "slots": N_SLOTS,
        "max_queue": MAX_QUEUE,
        "clients_levels": list(CLIENTS),
        "points": points,
        "overload": ovl,
        "interleaved": inter,
        "metrics": metrics,
    }
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    path = os.path.join(out_dir, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    best = max(points, key=lambda p: p["qps"])
    print(f"# wrote {path}")
    print(f"# peak qps={best['qps']} at {best['stations']} stations / "
          f"{best['clients']} clients; overload deterministic="
          f"{ovl['deterministic']}")
    return out


if __name__ == "__main__":
    main()
