"""Benchmark runner: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.csv_line).
Roofline reporting (from dry-run artifacts) appended when artifacts exist.
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    t0 = time.time()
    from benchmarks import (bench_alternatives, bench_bandpass,
                            bench_factor_analysis, bench_lsh_params,
                            bench_mad_sampling, bench_occurrence_filter,
                            bench_partitions, bench_scaling, bench_stream)
    suites = [
        ("factor_analysis(Fig10/Tab5)", bench_factor_analysis.main),
        ("occurrence_filter(Tab1)", bench_occurrence_filter.main),
        ("bandpass(Fig11)", bench_bandpass.main),
        ("lsh_params(Fig12/Fig6)", bench_lsh_params.main),
        ("partitions(Fig13)", bench_partitions.main),
        ("scaling(Fig14)", bench_scaling.main),
        ("mad_sampling(Tab6)", bench_mad_sampling.main),
        ("alternatives(Tab2)", bench_alternatives.main),
        ("stream(incremental_index)", bench_stream.main),
    ]
    failures = 0
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()[-1500:]}")
    if os.path.isdir("artifacts/dryrun"):
        print("# === roofline (from dry-run artifacts) ===")
        try:
            from benchmarks import roofline
            roofline.main("artifacts/dryrun")
        except Exception:
            print(f"# roofline FAILED:\n{traceback.format_exc()[-800:]}")
    print(f"# total bench time {time.time()-t0:.0f}s, "
          f"{failures} suite failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
