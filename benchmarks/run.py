"""Benchmark runner: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.csv_line).
Roofline reporting (from dry-run artifacts) appended when artifacts exist.

``--e2e`` runs only the streaming hot-path benchmark (BENCH_e2e.json);
``--quick`` shrinks it to the tier-1-safe smoke invocation
(``make bench-smoke``). ``--scenario`` adds the dirty-stream robustness
point (gap + glitch spurious suppression) to BENCH_stream.json, and
``--serve`` the concurrent serving-tier benchmark (BENCH_serve.json:
QPS / latency split / shed rate under closed-loop clients).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--e2e", action="store_true",
                    help="run only the end-to-end hot-path benchmark")
    ap.add_argument("--quick", action="store_true",
                    help="smoke-size the e2e benchmark")
    ap.add_argument("--scenario", action="store_true",
                    help="also record the dirty-stream robustness point "
                         "(BENCH_stream.json scenario key)")
    ap.add_argument("--serve", action="store_true",
                    help="also run the concurrent serving-tier benchmark "
                         "(BENCH_serve.json)")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.e2e or args.serve:
        if args.e2e:
            from benchmarks import bench_e2e
            bench_e2e.main(["--quick"] if args.quick else [])
        if args.scenario:
            from benchmarks import bench_stream
            bench_stream.main(["--scenario-only"])
        if args.serve:
            from benchmarks import bench_serve
            bench_serve.main(["--quick"] if args.quick else [])
        print(f"# total bench time {time.time()-t0:.0f}s")
        return

    from benchmarks import (bench_alternatives, bench_bandpass, bench_e2e,
                            bench_factor_analysis, bench_lsh_params,
                            bench_mad_sampling, bench_occurrence_filter,
                            bench_partitions, bench_scaling, bench_serve,
                            bench_stream)
    # bench_stream / bench_e2e parse argv — hand them an explicit list so
    # the runner's own flags (--quick) never leak in via sys.argv; the
    # remaining mains take no arguments
    suites = [
        ("factor_analysis(Fig10/Tab5)", lambda: bench_factor_analysis.main()),
        ("occurrence_filter(Tab1)", lambda: bench_occurrence_filter.main()),
        ("bandpass(Fig11)", lambda: bench_bandpass.main()),
        ("lsh_params(Fig12/Fig6)", lambda: bench_lsh_params.main()),
        ("partitions(Fig13)", lambda: bench_partitions.main()),
        ("scaling(Fig14)", lambda: bench_scaling.main()),
        ("mad_sampling(Tab6)", lambda: bench_mad_sampling.main()),
        ("alternatives(Tab2)", lambda: bench_alternatives.main()),
        ("stream(incremental_index)",
         lambda: bench_stream.main(["--scenario"])),
        ("stream_e2e(hot_path)",
         lambda: bench_e2e.main(["--quick"] if args.quick else [])),
        ("serve(query_tier)",
         lambda: bench_serve.main(["--quick"] if args.quick else [])),
    ]
    failures = 0
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()[-1500:]}")
    if os.path.isdir("artifacts/dryrun"):
        print("# === roofline (from dry-run artifacts) ===")
        try:
            from benchmarks import roofline
            roofline.main("artifacts/dryrun")
        except Exception:
            print(f"# roofline FAILED:\n{traceback.format_exc()[-800:]}")
    print(f"# total bench time {time.time()-t0:.0f}s, "
          f"{failures} suite failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
