"""Full FAST workload: multi-station detection with every paper
optimization toggled, reporting a factor-analysis-style breakdown
(paper §8.1) and final network detections vs injected ground truth.

``detect_events`` is the unified batch driver (one core, two drivers):
each configuration replays the archive through the streaming station-pool
step — one fused dispatch per block for all stations — so the streaming
data-quality guards are available to batch runs too. ``--block-fp`` sizes
the replay block; ``--occ-limit`` turns on the in-dispatch §6.5
occurrence limiter for the optimized configuration (useful when
reprocessing archives with known glitch trains).

Run:  PYTHONPATH=src python examples/detect_earthquakes.py [--duration 900]
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.core import (AlignConfig, DetectConfig, FingerprintConfig,
                        LSHConfig, SynthConfig, make_dataset)
from repro.core.detect import detect_events, recall_against_truth, \
    replay_config


def run(cfg_name: str, cfg: DetectConfig, waveforms, dataset, scfg=None):
    t0 = time.perf_counter()
    det, events, times, stats = detect_events(waveforms, cfg, scfg=scfg)
    wall = time.perf_counter() - t0
    rec = recall_against_truth(det, events, dataset, cfg.fingerprint)
    print(f"{cfg_name:28s} wall={wall:6.1f}s "
          f"detections={stats['detections']:3d} "
          f"recall={rec['recall']:.2f} "
          f"(stats={times.fingerprint_s:.1f} hash={times.hashgen_s:.1f} "
          f"fused={times.fused_step_s:.1f} align={times.align_s:.1f})")
    return wall, rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--block-fp", type=int, default=256,
                    help="replay block size (fingerprints per dispatch)")
    ap.add_argument("--occ-limit", type=int, default=0,
                    help="in-dispatch occurrence limiter for the optimized "
                         "run (0 = off; host §6.5 filter always applies)")
    args = ap.parse_args()

    dataset = make_dataset(SynthConfig(
        duration_s=args.duration, n_stations=3, n_sources=3,
        events_per_source=4, event_snr=3.0,
        repeating_noise_stations=(0,), hum_stations=(2,), seed=11))
    wf = dataset.waveforms
    print(f"dataset: {wf.shape[0]} stations × {wf.shape[1]} samples, "
          f"{len(dataset.event_times)} injected events\n")

    fp = FingerprintConfig(img_time=32, img_hop=4, top_k=200,
                           mad_sample_rate=1.0)
    base_align = AlignConfig(channel_threshold=3, min_cluster_sim=4,
                             min_cluster_size=1, min_stations=2,
                             onset_tol=int(10 * fp.fs / fp.lag_samples))

    # paper-faithful baseline: MinHash, no occurrence filter, full MAD
    baseline = DetectConfig(
        fingerprint=fp,
        lsh=LSHConfig(n_tables=100, n_funcs=4, n_matches=5,
                      use_minmax=False, min_dt=fp.overlap_fingerprints,
                      occurrence_frac=0.0),
        align=base_align)
    t_base, _ = run("baseline(minhash,k4m5)", baseline, wf, dataset)

    # + occurrence filter (§6.5)
    occ = dataclasses.replace(
        baseline, lsh=dataclasses.replace(baseline.lsh,
                                          occurrence_frac=0.05))
    run("+occurrence_filter", occ, wf, dataset)

    # + k↑ m↓ with matched S-curve (§6.3)
    kfun = dataclasses.replace(
        occ, lsh=dataclasses.replace(occ.lsh, n_funcs=6, n_matches=1))
    run("+increase_hash_funcs", kfun, wf, dataset)

    # + Min-Max hash (§6.2)
    mm = dataclasses.replace(
        kfun, lsh=dataclasses.replace(kfun.lsh, use_minmax=True))
    run("+minmax_hash", mm, wf, dataset)

    # + sampled MAD (§5.2) — the fully-optimized pipeline, with the
    # replay knobs threaded through (block size + in-dispatch limiter)
    opt = dataclasses.replace(
        mm, fingerprint=dataclasses.replace(fp, mad_sample_rate=0.1))
    scfg = replay_config(opt.lsh, block_fingerprints=args.block_fp)
    if args.occ_limit:
        scfg = dataclasses.replace(
            scfg, occ_limit=args.occ_limit,
            index=dataclasses.replace(
                scfg.index,
                occ_slots=opt.fingerprint.n_fingerprints(wf.shape[1])))
    t_opt, rec = run("+mad_sampling(=optimized)", opt, wf, dataset,
                     scfg=scfg)

    print(f"\ncumulative speedup: {t_base / t_opt:.1f}×  "
          f"final recall: {rec['recall']:.2f}")


if __name__ == "__main__":
    main()
