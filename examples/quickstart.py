"""Quickstart: detect reoccurring earthquakes in 10 minutes of synthetic
seismic data — the paper's full pipeline through the public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (AlignConfig, DetectConfig, FingerprintConfig,
                        LSHConfig, SynthConfig, make_dataset)
from repro.core.detect import detect_events, recall_against_truth


def main():
    # 1. Synthetic network: 3 stations, 3 reoccurring sources, repeating
    #    background noise at station 0 (the Figure-7 pathology).
    dataset = make_dataset(SynthConfig(
        duration_s=600.0, n_stations=3, n_sources=3, events_per_source=4,
        event_snr=3.0, repeating_noise_stations=(0,), seed=3))
    print(f"waveforms: {dataset.waveforms.shape} "
          f"({len(dataset.event_times)} injected events)")

    # 2. Pipeline config (paper Figure 2: fingerprint → LSH → align).
    fp = FingerprintConfig(img_time=32, img_hop=4, top_k=200,
                           mad_sample_rate=0.5)
    cfg = DetectConfig(
        fingerprint=fp,
        lsh=LSHConfig(n_tables=100, n_funcs=4, n_matches=2,
                      min_dt=fp.overlap_fingerprints,
                      occurrence_frac=0.05),
        align=AlignConfig(channel_threshold=3, min_cluster_sim=4,
                          min_cluster_size=1, min_stations=2,
                          onset_tol=int(10 * fp.fs / fp.lag_samples)))

    # 3. Detect.
    detections, station_events, times, stats = detect_events(
        dataset.waveforms, cfg)
    # batch = replay over the streaming core: the fused per-block dispatch
    # (fingerprint→hash→search in one program) is its own span-derived
    # stage, fused_step_s (search_s remains as a legacy alias)
    print(f"stage seconds: stats={times.fingerprint_s:.1f} "
          f"hashgen={times.hashgen_s:.1f} "
          f"fused_replay={times.fused_step_s:.1f} "
          f"align={times.align_s:.1f}")
    print(f"network detections: {stats['detections']}")

    # 4. Score against injected ground truth.
    rec = recall_against_truth(detections, station_events, dataset,
                               cfg.fingerprint)
    print(f"recall on reoccurring events: {rec['hits']}/{rec['detectable']}"
          f" = {rec['recall']:.2f}")
    assert rec["recall"] >= 0.7
    print("OK")


if __name__ == "__main__":
    main()
