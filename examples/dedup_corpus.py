"""The paper's technique as data infrastructure: LSH near-duplicate
detection over a token corpus (fingerprint → Min-Max LSH → postprocess,
exactly the FAST pipeline shape).

Run:  PYTHONPATH=src python examples/dedup_corpus.py
"""
import numpy as np

from repro.data.dedup import DedupConfig, find_duplicates


def main():
    rng = np.random.default_rng(0)
    n, s = 64, 256
    docs = rng.integers(1, 50_000, (n, s)).astype(np.int32)
    # inject: 8 exact duplicates + 8 near-duplicates (2% token noise)
    for j in range(8):
        docs[n - 16 + j] = docs[j]
    for j in range(8):
        d = docs[8 + j].copy()
        flips = rng.integers(0, s, size=s // 50)
        d[flips] = rng.integers(1, 50_000, size=flips.size)
        docs[n - 8 + j] = d

    keep, stats = find_duplicates(docs, DedupConfig())
    print(f"corpus: {n} docs × {s} tokens; injected 16 (near-)duplicates")
    print(f"candidate pairs from LSH: {stats['candidate_pairs']}, "
          f"verified: {stats['verified_dups']}, dropped: {stats['dropped']}")
    dropped = np.where(~keep)[0]
    print(f"dropped doc ids: {dropped.tolist()}")
    assert stats["dropped"] >= 14, stats
    assert keep[:48].sum() >= 46  # originals survive
    print("OK")


if __name__ == "__main__":
    main()
