"""End-to-end training driver: train a small LM on the synthetic corpus
with the LSH dedup stage enabled, checkpointing, and restart.

Defaults are CPU-sized (a ~5M-param model for a quick demo); pass
``--model-scale 100m --steps 300`` on real hardware for the full run.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse

from repro.launch import train as train_mod
from repro.models.config import ModelConfig


SCALES = {
    # ~5M params: fast on 1 CPU core
    "5m": ModelConfig(name="lm-5m", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=512, vocab_size=2048,
                      attn_q_block=64, attn_kv_block=64, loss_seq_chunk=64,
                      param_dtype="float32", compute_dtype="float32",
                      remat="none"),
    # ~100M params: the assignment's end-to-end target (run on a real chip)
    "100m": ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                        n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab_size=32768, attn_q_block=256,
                        attn_kv_block=256, loss_seq_chunk=256,
                        param_dtype="float32", compute_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-scale", default="5m", choices=list(SCALES))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = SCALES[args.model_scale]
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"LSH dedup ON")

    # Reuse the production driver with our model config injected.
    orig = train_mod.build_model_config
    train_mod.build_model_config = lambda a: cfg
    try:
        argv = ["--steps", str(args.steps), "--batch", str(args.batch),
                "--seq", str(args.seq), "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "10"]
        if args.resume:
            argv.append("--resume")
        result = train_mod.main(argv)
    finally:
        train_mod.build_model_config = orig
    # synthetic uniform-token corpus has little learnable signal on CPU
    # scales; assert training is stable (not diverging) rather than a
    # strict descent
    assert result["final_loss"] < result["first_loss"] + 0.05, result
    print(f"loss {result['first_loss']:.3f} → {result['final_loss']:.3f} "
          f"over {result['steps_run']} steps; "
          f"dedup dropped {result['dedup']['dropped']} near-duplicate "
          f"sequences of {result['dedup']['seen']}")


if __name__ == "__main__":
    main()
