"""Streaming FAST: continuous multi-station detection over chunked input.

The offline pipeline (examples/detect_earthquakes.py) sees the whole trace
at once; here the same synthetic network arrives as ~1-minute chunks and
the ``StreamingDetector`` maintains a device-resident incremental LSH
index per station — each chunk costs O(chunk), no re-sort of history.
Finishes by comparing streamed detections against the injected ground
truth and against an offline re-run of the identical configuration.

With ``--bounded`` the detector runs in the sliding-window regime: index
entries expire beyond the detection window, candidate pairs retire through
the rolling occurrence filter (host state bounded by the window, not the
stream), and multi-station detections print as near-real-time alerts the
moment their windows close instead of only at finalize.

With ``--locate`` (implies ``--bounded``) the synthetic network gets real
station geometry and physical moveouts, and the ISSUE-9 location tier runs
on every association: alerts carry a migration-stacked origin and a
relative magnitude, moveout-inconsistent coincidences are rejected, and
upgraded alerts (a station joining late) re-emit flagged.

Run:  PYTHONPATH=src python examples/stream_detect.py [--duration 600]
      PYTHONPATH=src python examples/stream_detect.py --bounded
      PYTHONPATH=src python examples/stream_detect.py --locate
"""
import argparse
import time

import numpy as np

from repro.configs.fast_seismic import (located_smoke_config, smoke_config,
                                        stream_bounded_smoke_config,
                                        stream_smoke_config)
from repro.core import SynthConfig, make_dataset
from repro.core.detect import detect_events, recall_against_truth
from repro.core.locate import LOC_NONE, MAG_NONE
from repro.stream import StreamingDetector


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--chunk-s", type=float, default=60.0)
    ap.add_argument("--stations", type=int, default=3)
    ap.add_argument("--bounded", action="store_true",
                    help="sliding window + rolling filter + live alerts")
    ap.add_argument("--locate", action="store_true",
                    help="station geometry + location/magnitude tier "
                         "(implies --bounded)")
    args = ap.parse_args()

    cfg = located_smoke_config() if args.locate else smoke_config()
    scfg = (stream_bounded_smoke_config() if args.bounded or args.locate
            else stream_smoke_config())
    dataset = make_dataset(SynthConfig(
        duration_s=args.duration, n_stations=args.stations, n_sources=3,
        events_per_source=4, event_snr=3.0,
        repeating_noise_stations=(0,), seed=11,
        physical_geometry=args.locate))
    wf = dataset.waveforms
    chunk = int(args.chunk_s * cfg.fingerprint.fs)

    det = StreamingDetector(cfg, scfg, n_stations=args.stations,
                            station_xy=dataset.station_xy)
    t0 = time.perf_counter()
    for start in range(0, wf.shape[1], chunk):
        n_alerts = len(det.alerts)
        det.push(wf[:, start: start + chunk])
        for rows in det.alerts[n_alerts:]:
            for dt, onset, n_st, score, upg, x_mkm, y_mkm, mag_m in rows:
                lag_s = cfg.fingerprint.lag_samples / cfg.fingerprint.fs
                where = ("" if x_mkm == LOC_NONE else
                         f" at ({x_mkm / 1e3:.1f}, {y_mkm / 1e3:.1f}) km")
                size = ("" if mag_m == MAG_NONE
                        else f" dmag={mag_m / 1e3:+.2f}")
                tag = " UPGRADE" if upg else ""
                print(f"  ALERT t≈{onset * lag_s:6.0f}s dt={dt * lag_s:.0f}s "
                      f"stations={n_st} score={score}{where}{size}{tag} "
                      f"(stream at {(start + chunk) / cfg.fingerprint.fs:.0f}s)")
    detections, events, stats = det.finalize()
    stream_wall = time.perf_counter() - t0
    rec = recall_against_truth(detections, events, dataset, cfg.fingerprint)
    ing = stats["ingest"][0]
    print(f"streaming   wall={stream_wall:6.1f}s "
          f"detections={stats.get('detections', 0):3d} "
          f"recall={rec['recall']:.2f} "
          f"(chunk p50={ing['chunk_ms_p50']:.0f}ms "
          f"p95={ing['chunk_ms_p95']:.0f}ms "
          f"{ing['samples_per_s']:.0f} samples/s/station)")
    # the ISSUE-6 telemetry view: real-time factor, in-dispatch drop
    # breakdown, wall histograms — the same snapshot serve_detect and the
    # BENCH artifacts embed
    m = det.metrics_snapshot()
    fused_p95_ms = 1e3 * m["histograms"]["fused_step_wall_seconds"]["p95"]
    print(f"telemetry   rtf={m['rtf']:.0f}x realtime "
          f"pairs={m['drops']['pairs_emitted']} "
          f"masked={m['drops']['masked_fingerprints']} "
          f"limited={m['drops']['limited_pairs']} "
          f"fused p95={fused_p95_ms:.1f}ms steps={m['watchdog']['steps']} "
          f"stragglers={m['watchdog']['stragglers']}")

    if args.locate and detections is not None:
        v = np.asarray(detections["valid"])
        errs = [np.min(np.linalg.norm(
                    dataset.source_xy
                    - np.array([detections["x_km"][g],
                                detections["y_km"][g]]), axis=1))
                for g in np.nonzero(v)[0]]
        lv = det.telemetry.locate_view()
        med = f"{np.median(errs):.1f}" if errs else "n/a"
        print(f"located     {int(v.sum()):3d} detections "
              f"median_origin_err={med} km "
              f"moveout_rejected={lv['moveout_rejected']} "
              f"stack p50={lv['stack_wall']['p50_ms']:.1f}ms")

    t0 = time.perf_counter()
    off_det, off_events, _, off_stats = detect_events(
        wf, cfg, station_xy=dataset.station_xy)
    off_wall = time.perf_counter() - t0
    off_rec = recall_against_truth(off_det, off_events, dataset,
                                   cfg.fingerprint)
    print(f"offline     wall={off_wall:6.1f}s "
          f"detections={off_stats['detections']:3d} "
          f"recall={off_rec['recall']:.2f}")


if __name__ == "__main__":
    main()
