"""Batched serving demo: continuous-batching engine over decode slots.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 8
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    stats = serve_main(["--arch", "smoke", "--requests",
                        str(args.requests), "--slots", str(args.slots),
                        "--max-new", "12", "--prompt-len", "16",
                        "--max-len", "64"])
    print(f"served {stats['requests']} requests, "
          f"{stats['generated']} tokens at {stats['tokens_per_s']} tok/s "
          f"({stats['ticks']} batched decode ticks)")


if __name__ == "__main__":
    main()
